//! Minimal dense linear algebra used across the stack.
//!
//! Row-major `f32` matrices (matching the PJRT buffer layout) plus the
//! handful of BLAS-1/3 routines the solvers and feature maps need. The
//! GEMM is cache-blocked; it is not trying to beat MKL, only to keep the
//! native engine within a small factor of memory bandwidth so the
//! benchmark *shapes* are honest.

pub mod eigen;
pub mod fft;
pub mod fwht;
pub mod matrix;
pub mod sparse;

pub use eigen::{eigh, inv_sqrt_psd};
pub use fwht::{fwht, fwht_checked};
pub use matrix::Matrix;
pub use sparse::{SparseMatrix, SparseRow};

/// Smallest power of two ≥ `n` (and ≥ 1): the padded length shared by
/// the radix-2 transforms — [`fft`](crate::linalg::fft::fft) widths
/// (tensorsketch) and the [`fwht`] buffers of [`crate::structured`].
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// Copy `x` into a fresh zero-padded buffer of length [`next_pow2`]
/// `(x.len())` — the canonical way arbitrary input dims enter the
/// power-of-two transforms.
pub fn zero_pad_pow2(x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; next_pow2(x.len())];
    out[..x.len()].copy_from_slice(x);
    out
}

/// Dot product on the dispatched [`crate::simd`] kernel path (the
/// scalar path is the original 4-lane accumulation; exact association
/// differences between paths are bounded by
/// [`crate::simd::dot_ulp_bound`] and irrelevant at the tolerances
/// this library tests).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    crate::simd::dot(a, b)
}

/// `y += alpha * x` on the dispatched [`crate::simd`] kernel path.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    crate::simd::axpy(alpha, x, y);
}

/// `x *= alpha` on the dispatched [`crate::simd`] kernel path
/// (bitwise identical across paths — pure IEEE multiplies).
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    crate::simd::scale(alpha, x);
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// 1-norm.
#[inline]
pub fn norm1(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

/// Normalize `x` to unit 2-norm in place; returns the original norm.
/// Zero vectors are left untouched.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm2(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

/// Mean of a slice (f64 accumulation).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Build a symmetric `n × n` matrix from its lower triangle: entry
/// `(i, j)` for `j ≤ i` comes from `entry(i, j)`, computed in row
/// blocks balanced for the triangular cost across the worker budget
/// (`threads == 0` = the global [`crate::parallel`] knob, with the
/// small-work cutoff scaled by `unit_work`, the approximate mul-adds
/// per entry). The upper triangle is mirrored with pure copies, so any
/// thread count is bit-identical to the serial fill. Shared scaffold of
/// [`crate::kernels::gram`] and [`crate::features::feature_gram`].
pub fn symmetric_from_lower<F>(n: usize, threads: usize, unit_work: usize, entry: F) -> Matrix
where
    F: Fn(usize, usize) -> f32 + Sync,
{
    let mut g = Matrix::zeros(n, n);
    if n == 0 {
        return g;
    }
    let work = (n.saturating_mul(n) / 2).saturating_mul(unit_work.max(1));
    let t = crate::parallel::resolve_threads_for_work(threads, n, work);
    let ranges = crate::parallel::partition_triangular(n, t);
    crate::parallel::par_chunks_ranges(n, g.as_mut_slice(), &ranges, |row0, block| {
        for (i, g_row) in block.chunks_mut(n).enumerate() {
            let gi = row0 + i;
            for (j, slot) in g_row[..=gi].iter_mut().enumerate() {
                *slot = entry(gi, j);
            }
        }
    });
    for i in 0..n {
        for j in 0..i {
            let v = g.get(i, j);
            g.set(j, i, v);
        }
    }
    g
}

/// Smallest eigenvalue estimate of a symmetric matrix by shifted power
/// iteration: run power iteration on `c·I − A` (with `c` = a Gershgorin
/// upper bound on `λ_max`), whose top eigenvalue is `c − λ_min(A)`.
///
/// Used by the PSD property tests on kernel Gram matrices.
pub fn min_eigenvalue_sym(a: &Matrix, iters: usize) -> f64 {
    let n = a.rows();
    assert_eq!(n, a.cols(), "matrix must be square");
    if n == 0 {
        return 0.0;
    }
    // Gershgorin bound on the spectral radius.
    let mut c = 0.0f64;
    for i in 0..n {
        let row = a.row(i);
        let r: f64 = row.iter().map(|v| v.abs() as f64).sum();
        c = c.max(r);
    }
    if c == 0.0 {
        return 0.0;
    }
    let mut v = vec![1.0f64 / (n as f64).sqrt(); n];
    let mut w = vec![0.0f64; n];
    let mut lambda_shifted = 0.0f64;
    for _ in 0..iters {
        // w = (c I - A) v
        for i in 0..n {
            let row = a.row(i);
            let mut s = 0.0f64;
            for j in 0..n {
                s += row[j] as f64 * v[j];
            }
            w[i] = c * v[i] - s;
        }
        let nw = (w.iter().map(|x| x * x).sum::<f64>()).sqrt();
        if nw == 0.0 {
            return 0.0; // A = c I exactly on this subspace
        }
        lambda_shifted = nw;
        for i in 0..n {
            v[i] = w[i] / nw;
        }
    }
    c - lambda_shifted
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // The length-scaled bound shared with the SIMD parity tests
        // (~5e-4 at this length) — tight enough that a kernel
        // regression can't hide under a loose blanket epsilon.
        let a: Vec<f32> = (0..131).map(|i| (i as f32 * 0.1).sin()).collect();
        let b: Vec<f32> = (0..131).map(|i| (i as f32 * 0.2).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let bound = crate::simd::dot_ulp_bound(&a, &b);
        assert!(bound < 1e-3, "bound {bound} should be tighter than the old epsilon");
        assert!((dot(&a, &b) - naive).abs() <= bound);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn normalize_unit() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert_eq!(n, 5.0);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn symmetric_from_lower_builds_symmetric() {
        // Lower-triangle entries land as given, upper mirrors them,
        // and thread counts (including > n) never change the result.
        let want = symmetric_from_lower(5, 1, 1, |i, j| (i * 10 + j) as f32);
        for i in 0..5 {
            for j in 0..=i {
                assert_eq!(want.get(i, j), (i * 10 + j) as f32);
                assert_eq!(want.get(j, i), want.get(i, j));
            }
        }
        for threads in [2usize, 3, 64] {
            assert_eq!(symmetric_from_lower(5, threads, 1, |i, j| (i * 10 + j) as f32), want);
        }
        assert_eq!(symmetric_from_lower(0, 4, 1, |_, _| 1.0).rows(), 0);
    }

    #[test]
    fn min_eig_diagonal() {
        let mut a = Matrix::zeros(3, 3);
        a.set(0, 0, 5.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, -1.0);
        let e = min_eigenvalue_sym(&a, 500);
        assert!((e - (-1.0)).abs() < 1e-3, "e={e}");
    }

    #[test]
    fn min_eig_psd_gram() {
        // Gram matrix of random vectors is PSD.
        let mut rng = crate::rng::Rng::seed_from(1);
        let n = 12;
        let d = 6;
        let pts: Vec<Vec<f32>> =
            (0..n).map(|_| (0..d).map(|_| rng.f32() - 0.5).collect()).collect();
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g.set(i, j, dot(&pts[i], &pts[j]));
            }
        }
        let e = min_eigenvalue_sym(&g, 800);
        assert!(e > -1e-4, "gram should be PSD, min eig {e}");
    }
}
