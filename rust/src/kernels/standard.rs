//! The paper's example kernels (§3.2) and the two kernel transformers
//! used by its constructions (scaling, §3; truncation, §4.2).

use super::DotProductKernel;
use crate::kernels::series::binomial;

/// Homogeneous polynomial kernel `K(x, y) = ⟨x, y⟩^p`.
///
/// Inseparable, hence *not* covered by Vedaldi & Zisserman's homogeneous
/// additive maps — one of the paper's motivating examples.
#[derive(Clone, Copy, Debug)]
pub struct Homogeneous {
    /// Degree `p ≥ 1`.
    pub degree: u32,
}

impl Homogeneous {
    pub fn new(degree: u32) -> Self {
        assert!(degree >= 1, "degree must be >= 1");
        Homogeneous { degree }
    }
}

impl DotProductKernel for Homogeneous {
    fn name(&self) -> String {
        format!("homogeneous(p={})", self.degree)
    }

    fn coeff(&self, n: u32) -> f64 {
        if n == self.degree {
            1.0
        } else {
            0.0
        }
    }

    fn f(&self, t: f64) -> f64 {
        t.powi(self.degree as i32)
    }

    fn f_prime(&self, t: f64) -> f64 {
        self.degree as f64 * t.powi(self.degree as i32 - 1)
    }

    fn max_order(&self) -> Option<u32> {
        Some(self.degree)
    }
}

/// Non-homogeneous polynomial kernel `K(x, y) = (⟨x, y⟩ + r)^p`, `r > 0`.
///
/// Maclaurin: `a_n = C(p, n) r^(p−n)`.
#[derive(Clone, Copy, Debug)]
pub struct Polynomial {
    pub degree: u32,
    pub offset: f64,
}

impl Polynomial {
    pub fn new(degree: u32, offset: f64) -> Self {
        assert!(degree >= 1, "degree must be >= 1");
        assert!(offset >= 0.0, "offset must be >= 0 for positive definiteness");
        Polynomial { degree, offset }
    }
}

impl DotProductKernel for Polynomial {
    fn name(&self) -> String {
        format!("polynomial(p={}, r={})", self.degree, self.offset)
    }

    fn coeff(&self, n: u32) -> f64 {
        if n > self.degree {
            0.0
        } else {
            binomial(self.degree, n) * self.offset.powi((self.degree - n) as i32)
        }
    }

    fn f(&self, t: f64) -> f64 {
        (t + self.offset).powi(self.degree as i32)
    }

    fn f_prime(&self, t: f64) -> f64 {
        self.degree as f64 * (t + self.offset).powi(self.degree as i32 - 1)
    }

    fn max_order(&self) -> Option<u32> {
        Some(self.degree)
    }
}

/// Exponential dot product kernel `K(x, y) = exp(⟨x, y⟩ / σ²)`.
///
/// Maclaurin: `a_n = σ^(−2n) / n!`. Universal on compact sets
/// (Steinwart 2001); the Gaussian RBF is its normalized version.
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    /// Width parameter `σ²`.
    pub sigma2: f64,
}

impl Exponential {
    pub fn new(sigma2: f64) -> Self {
        assert!(sigma2 > 0.0, "sigma^2 must be positive");
        Exponential { sigma2 }
    }
}

impl DotProductKernel for Exponential {
    fn name(&self) -> String {
        format!("exponential(sigma2={})", self.sigma2)
    }

    fn coeff(&self, n: u32) -> f64 {
        // a_n = (1/sigma2)^n / n!, computed multiplicatively to avoid
        // overflowing n! for large n.
        let mut a = 1.0f64;
        for i in 1..=n {
            a *= 1.0 / (self.sigma2 * i as f64);
        }
        a
    }

    fn f(&self, t: f64) -> f64 {
        (t / self.sigma2).exp()
    }

    fn f_prime(&self, t: f64) -> f64 {
        (t / self.sigma2).exp() / self.sigma2
    }
}

/// Vovk's real polynomial kernel
/// `K(x, y) = (1 − ⟨x, y⟩^p) / (1 − ⟨x, y⟩) = Σ_{n<p} ⟨x, y⟩^n`.
#[derive(Clone, Copy, Debug)]
pub struct VovkReal {
    pub degree: u32,
}

impl VovkReal {
    pub fn new(degree: u32) -> Self {
        assert!(degree >= 1);
        VovkReal { degree }
    }
}

impl DotProductKernel for VovkReal {
    fn name(&self) -> String {
        format!("vovk-real(p={})", self.degree)
    }

    fn coeff(&self, n: u32) -> f64 {
        if n < self.degree {
            1.0
        } else {
            0.0
        }
    }

    fn f(&self, t: f64) -> f64 {
        if (t - 1.0).abs() < 1e-12 {
            self.degree as f64 // limit of the geometric sum at t = 1
        } else {
            (1.0 - t.powi(self.degree as i32)) / (1.0 - t)
        }
    }

    fn f_prime(&self, t: f64) -> f64 {
        // d/dt Σ_{n<p} t^n = Σ_{1<=n<p} n t^(n-1)
        let mut acc = 0.0;
        let mut pow = 1.0;
        for n in 1..self.degree {
            acc += n as f64 * pow;
            pow *= t;
        }
        acc
    }

    fn max_order(&self) -> Option<u32> {
        Some(self.degree.saturating_sub(1))
    }
}

/// Vovk's infinite polynomial kernel `K(x, y) = 1 / (1 − ⟨x, y⟩)`
/// (`a_n = 1` for all n; radius of convergence 1 — use [`Scaled`] to keep
/// data strictly inside it).
#[derive(Clone, Copy, Debug, Default)]
pub struct VovkInfinite;

impl DotProductKernel for VovkInfinite {
    fn name(&self) -> String {
        "vovk-infinite".to_string()
    }

    fn coeff(&self, _n: u32) -> f64 {
        1.0
    }

    fn f(&self, t: f64) -> f64 {
        assert!(t.abs() < 1.0, "vovk-infinite defined only for |t| < 1, got {t}");
        1.0 / (1.0 - t)
    }

    fn f_prime(&self, t: f64) -> f64 {
        assert!(t.abs() < 1.0);
        1.0 / ((1.0 - t) * (1.0 - t))
    }

    fn radius(&self) -> f64 {
        1.0
    }
}

/// The paper's scaling construction (§3, end): if `f` is defined only on
/// `(−γ, γ)` pick `c > I/γ` and use `g(x) = f(x/c)`, implicitly scaling
/// the data down by `c`. Maclaurin: `g`'s coefficients are `a_n / c^n`;
/// the radius of convergence grows by `c`.
#[derive(Clone, Debug)]
pub struct Scaled<K> {
    pub inner: K,
    pub c: f64,
}

impl<K: DotProductKernel> Scaled<K> {
    pub fn new(inner: K, c: f64) -> Self {
        assert!(c > 0.0);
        Scaled { inner, c }
    }
}

impl<K: DotProductKernel> DotProductKernel for Scaled<K> {
    fn name(&self) -> String {
        format!("scaled(c={}, {})", self.c, self.inner.name())
    }

    fn coeff(&self, n: u32) -> f64 {
        self.inner.coeff(n) / self.c.powi(n as i32)
    }

    fn f(&self, t: f64) -> f64 {
        self.inner.f(t / self.c)
    }

    fn f_prime(&self, t: f64) -> f64 {
        self.inner.f_prime(t / self.c) / self.c
    }

    fn radius(&self) -> f64 {
        self.inner.radius() * self.c
    }

    fn max_order(&self) -> Option<u32> {
        self.inner.max_order()
    }
}

/// The §4.2 truncated kernel `K̃(x, y) = Σ_{n ≤ k} a_n ⟨x, y⟩^n`.
///
/// Satisfies Schoenberg's condition itself, so it is positive definite,
/// and `sup |K̃ − K| ≤ Σ_{n>k} a_n R^{2n}` on `B_1(0, R)`.
#[derive(Clone, Debug)]
pub struct Truncated<K> {
    pub inner: K,
    pub order: u32,
}

impl<K: DotProductKernel> Truncated<K> {
    pub fn new(inner: K, order: u32) -> Self {
        Truncated { inner, order }
    }
}

impl<K: DotProductKernel> DotProductKernel for Truncated<K> {
    fn name(&self) -> String {
        format!("truncated(k={}, {})", self.order, self.inner.name())
    }

    fn coeff(&self, n: u32) -> f64 {
        if n <= self.order {
            self.inner.coeff(n)
        } else {
            0.0
        }
    }

    fn f(&self, t: f64) -> f64 {
        // Finite Horner evaluation of the truncated series.
        let mut acc = 0.0;
        for n in (0..=self.order).rev() {
            acc = acc * t + self.inner.coeff(n);
        }
        acc
    }

    fn f_prime(&self, t: f64) -> f64 {
        let mut acc = 0.0;
        for n in (1..=self.order).rev() {
            acc = acc * t + n as f64 * self.inner.coeff(n);
        }
        acc
    }

    fn max_order(&self) -> Option<u32> {
        Some(match self.inner.max_order() {
            Some(m) => m.min(self.order),
            None => self.order,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gram;
    use crate::linalg::{min_eigenvalue_sym, Matrix};
    use crate::rng::Rng;

    /// Σ a_n t^n via the coefficients must reproduce the closed form.
    fn check_series_consistency(k: &dyn DotProductKernel, t: f64, n_terms: u32, tol: f64) {
        let mut acc = 0.0;
        let mut pow = 1.0;
        for n in 0..=n_terms {
            acc += k.coeff(n) * pow;
            pow *= t;
        }
        let direct = k.f(t);
        assert!(
            (acc - direct).abs() <= tol * (1.0 + direct.abs()),
            "{}: series {acc} vs f {direct} at t={t}",
            k.name()
        );
    }

    /// Numerical derivative must match f_prime.
    fn check_derivative(k: &dyn DotProductKernel, t: f64) {
        let h = 1e-6;
        let num = (k.f(t + h) - k.f(t - h)) / (2.0 * h);
        let ana = k.f_prime(t);
        assert!(
            (num - ana).abs() < 1e-3 * (1.0 + ana.abs()),
            "{}: f' numeric {num} vs analytic {ana} at t={t}",
            k.name()
        );
    }

    #[test]
    fn all_kernels_series_and_derivative_consistent() {
        let kernels: Vec<Box<dyn DotProductKernel>> = vec![
            Box::new(Homogeneous::new(10)),
            Box::new(Polynomial::new(10, 1.0)),
            Box::new(Polynomial::new(3, 0.5)),
            Box::new(Exponential::new(1.0)),
            Box::new(Exponential::new(4.0)),
            Box::new(VovkReal::new(6)),
            Box::new(VovkInfinite),
            Box::new(Scaled::new(VovkInfinite, 4.0)),
            Box::new(Truncated::new(Exponential::new(1.0), 8)),
        ];
        for k in &kernels {
            for &t in &[-0.5, -0.1, 0.0, 0.3, 0.8] {
                check_series_consistency(k.as_ref(), t, 120, 1e-8);
                check_derivative(k.as_ref(), t);
            }
        }
    }

    #[test]
    fn all_coefficients_nonnegative() {
        // Schoenberg's condition — every built-in kernel must satisfy it.
        let kernels: Vec<Box<dyn DotProductKernel>> = vec![
            Box::new(Homogeneous::new(7)),
            Box::new(Polynomial::new(10, 1.0)),
            Box::new(Exponential::new(0.5)),
            Box::new(VovkReal::new(4)),
            Box::new(VovkInfinite),
            Box::new(Scaled::new(Exponential::new(1.0), 2.0)),
            Box::new(Truncated::new(Polynomial::new(5, 1.0), 3)),
        ];
        for k in &kernels {
            for n in 0..60 {
                assert!(k.coeff(n) >= 0.0, "{} a_{n} < 0", k.name());
            }
        }
    }

    #[test]
    fn gram_matrices_are_psd() {
        // Theorem 1: these kernels are PD on the unit ball. Check the
        // min eigenvalue of random Gram matrices.
        let mut rng = Rng::seed_from(42);
        let kernels: Vec<Box<dyn DotProductKernel>> = vec![
            Box::new(Homogeneous::new(4)),
            Box::new(Polynomial::new(6, 1.0)),
            Box::new(Exponential::new(1.0)),
            Box::new(VovkReal::new(5)),
            Box::new(Scaled::new(VovkInfinite, 2.0)),
        ];
        for k in &kernels {
            let n = 15;
            let d = 5;
            let mut rows = Vec::new();
            for _ in 0..n {
                let mut v: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
                crate::linalg::normalize(&mut v);
                // stay strictly inside the unit ball
                crate::linalg::scale(0.9, &mut v);
                rows.push(v);
            }
            let x = Matrix::from_rows(&rows).unwrap();
            let g = gram(k.as_ref(), &x);
            let e = min_eigenvalue_sym(&g, 600);
            assert!(e > -1e-3, "{} gram min eig {e}", k.name());
        }
    }

    #[test]
    fn polynomial_binomial_expansion() {
        let k = Polynomial::new(10, 1.0);
        // (1 + t)^10: a_0 = 1, a_1 = 10, a_2 = 45, sum at t=1 is 2^10.
        assert_eq!(k.coeff(0), 1.0);
        assert_eq!(k.coeff(1), 10.0);
        assert_eq!(k.coeff(2), 45.0);
        assert_eq!(k.coeff(11), 0.0);
        let total: f64 = (0..=10).map(|n| k.coeff(n)).sum();
        assert!((total - 1024.0).abs() < 1e-9);
    }

    #[test]
    fn homogeneous_has_single_term() {
        let k = Homogeneous::new(10);
        assert_eq!(k.coeff(10), 1.0);
        assert_eq!(k.coeff(9), 0.0);
        assert_eq!(k.coeff(0), 0.0);
        assert_eq!(k.max_order(), Some(10));
        // H0/1 has nothing to absorb: a_0 = a_1 = 0.
        assert_eq!(k.coeff(0) + k.coeff(1), 0.0);
    }

    #[test]
    fn vovk_real_at_one_is_degree() {
        let k = VovkReal::new(6);
        assert!((k.f(1.0) - 6.0).abs() < 1e-9);
        assert!((k.f(0.5) - (1.0 - 0.5f64.powi(6)) / 0.5).abs() < 1e-12);
    }

    #[test]
    fn scaled_extends_radius() {
        let k = Scaled::new(VovkInfinite, 4.0);
        assert_eq!(k.radius(), 4.0);
        // g(t) = 1 / (1 - t/4); safe at t = 2 where the raw kernel blows up.
        assert!((k.f(2.0) - 2.0).abs() < 1e-12);
        assert!((k.coeff(3) - 1.0 / 64.0).abs() < 1e-15);
    }

    #[test]
    fn truncated_tail_bound_holds() {
        // §4.2: sup over the ball of |K̃ - K| <= tail mass.
        let inner = Exponential::new(1.0);
        let k = Truncated::new(inner, 4);
        let series = crate::kernels::MaclaurinSeries::materialize(&inner, 60, 1.0);
        let bound = series.tail_mass(4);
        let mut rng = Rng::seed_from(3);
        for _ in 0..200 {
            let t = rng.f64() * 2.0 - 1.0; // <x,y> in [-1, 1] for R = 1
            let err = (k.f(t) - inner.f(t)).abs();
            assert!(err <= bound + 1e-12, "err {err} > bound {bound} at t={t}");
        }
    }

    #[test]
    #[should_panic]
    fn vovk_infinite_rejects_out_of_radius() {
        VovkInfinite.f(1.5);
    }
}
