//! Positive definite dot product kernels `K(x, y) = f(⟨x, y⟩)`.
//!
//! By Schoenberg's theorem (paper Theorem 1 / Corollary 5), `f` yields a
//! positive definite kernel over every finite dimensional Euclidean space
//! iff it is analytic with a Maclaurin expansion `f(t) = Σ a_n t^n` whose
//! coefficients are all non-negative. The [`DotProductKernel`] trait
//! exposes exactly that structure — `f`, `f'`, the coefficients `a_n`,
//! and the radius of convergence — because every quantity in the paper's
//! analysis (estimator weights `√(a_N / P[N])`, estimator bound
//! `C_Ω = p·f(pR²)`, Lipschitz constant `L = R f'(R²) + p² R √d f'(pR²)`,
//! truncation tails `Σ_{n>k} a_n R^{2n}`) is a functional of them.
//!
//! Provided kernels (paper §3.2): [`Homogeneous`], [`Polynomial`],
//! [`Exponential`], [`VovkReal`], [`VovkInfinite`], plus the [`Scaled`]
//! wrapper implementing the paper's `g(x) = f(x/c)` trick for finite
//! radii of convergence and [`Truncated`] for the §4.2 alternative map.

pub mod series;
pub mod standard;

pub use series::{binomial, MaclaurinSeries, Truncation};
pub use standard::{Exponential, Homogeneous, Polynomial, Scaled, Truncated, VovkInfinite, VovkReal};

use crate::linalg::dot;

/// A positive definite dot product kernel, exposed through its defining
/// scalar function `f` and Maclaurin coefficients.
pub trait DotProductKernel: Send + Sync {
    /// Human-readable name used by configs, logs and bench tables.
    fn name(&self) -> String;

    /// Maclaurin coefficient `a_n ≥ 0` of `f(t) = Σ_n a_n t^n`.
    fn coeff(&self, n: u32) -> f64;

    /// Evaluate `f(t)` (closed form; must agree with the series inside
    /// the radius of convergence).
    fn f(&self, t: f64) -> f64;

    /// Evaluate `f'(t)` (closed form).
    fn f_prime(&self, t: f64) -> f64;

    /// Radius of convergence of the Maclaurin series
    /// (`f64::INFINITY` for entire functions).
    fn radius(&self) -> f64 {
        f64::INFINITY
    }

    /// Largest `n` with `a_n > 0`, if the expansion is finite
    /// (polynomial kernels); `None` for infinite expansions.
    fn max_order(&self) -> Option<u32> {
        None
    }

    /// Kernel value on explicit vectors: `f(⟨x, y⟩)`.
    fn eval(&self, x: &[f32], y: &[f32]) -> f64 {
        self.f(dot(x, y) as f64)
    }

    /// The estimator bound of Lemma 8, `C_Ω = p · f(p R²)`: with the
    /// normalized external measure (see [`crate::rng::Geometric`]) the
    /// exact bound is `f(pR²)·p/(p−1)`, which equals the paper's `p·f(pR²)`
    /// at the recommended `p = 2`.
    fn estimator_bound(&self, p: f64, r: f64) -> f64 {
        self.f(p * r * r) * p / (p - 1.0)
    }

    /// The Lipschitz constant bound of §4.1:
    /// `L = R f'(R²) + p² R √d f'(pR²)` (with the same `p/(p−1)`
    /// normalization correction folded into the second term).
    fn lipschitz_bound(&self, p: f64, r: f64, d: usize) -> f64 {
        r * self.f_prime(r * r)
            + p * p / (p - 1.0) * r * (d as f64).sqrt() * self.f_prime(p * r * r)
    }
}

/// Gram matrix of a kernel over a point set (rows of `x`), using the
/// global [`crate::parallel`] worker budget.
pub fn gram(kernel: &dyn DotProductKernel, x: &crate::linalg::Matrix) -> crate::linalg::Matrix {
    gram_threads(kernel, x, 0)
}

/// [`gram`] with an explicit worker count (`0` = the global knob).
/// Each entry is one independent kernel evaluation of cost `O(d)`, so
/// the triangular fill parallelizes bit-identically (see
/// [`crate::linalg::symmetric_from_lower`]).
pub fn gram_threads(
    kernel: &dyn DotProductKernel,
    x: &crate::linalg::Matrix,
    threads: usize,
) -> crate::linalg::Matrix {
    crate::linalg::symmetric_from_lower(x.rows(), threads, x.cols(), |i, j| {
        kernel.eval(x.row(i), x.row(j)) as f32
    })
}

/// Mean absolute elementwise difference between two Gram matrices — the
/// error metric of the paper's Figure 1 ("average absolute difference
/// between the entries of the kernel matrix...").
pub fn mean_abs_gram_error(a: &crate::linalg::Matrix, b: &crate::linalg::Matrix) -> f64 {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let n = a.rows() * a.cols();
    if n == 0 {
        return 0.0;
    }
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(x, y)| (x - y).abs() as f64)
        .sum::<f64>()
        / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn eval_matches_f_of_dot() {
        let k = Polynomial::new(3, 1.0);
        let x = vec![0.5f32, 0.5];
        let y = vec![0.2f32, -0.1];
        let t = dot(&x, &y) as f64;
        assert!((k.eval(&x, &y) - (1.0 + t).powi(3)).abs() < 1e-9);
    }

    #[test]
    fn gram_is_symmetric() {
        let k = Exponential::new(1.0);
        let x = Matrix::from_rows(&[vec![0.3, 0.1], vec![-0.2, 0.4], vec![0.0, 0.9]]).unwrap();
        let g = gram(&k, &x);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn gram_error_metric() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 8.]).unwrap();
        assert!((mean_abs_gram_error(&a, &b) - 1.0).abs() < 1e-12);
        assert_eq!(mean_abs_gram_error(&Matrix::zeros(0, 0), &Matrix::zeros(0, 0)), 0.0);
    }

    #[test]
    fn estimator_bound_matches_paper_at_p2() {
        // Lemma 8: |Z(x)Z(y)| <= p f(p R^2) at p = 2.
        let k = Exponential::new(1.0);
        let b = k.estimator_bound(2.0, 1.0);
        assert!((b - 2.0 * (2.0f64).exp()).abs() < 1e-9);
    }

    #[test]
    fn lipschitz_bound_positive_and_monotone_in_d() {
        let k = Polynomial::new(10, 1.0);
        let l8 = k.lipschitz_bound(2.0, 1.0, 8);
        let l128 = k.lipschitz_bound(2.0, 1.0, 128);
        assert!(l8 > 0.0 && l128 > l8);
    }
}
