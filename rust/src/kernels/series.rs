//! Maclaurin series utilities shared by the kernels and the feature maps.

use super::DotProductKernel;

/// Generalized binomial coefficient `C(n, k)` in `f64` (exact for the
/// ranges used here: n ≤ ~60).
pub fn binomial(n: u32, k: u32) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Outcome of a §4.2 truncation query (see
/// [`MaclaurinSeries::truncation`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Truncation {
    /// Chosen truncation order (capped at the materialized length).
    pub order: u32,
    /// Tail mass `Σ_{n>order} a_n R^{2n}` actually achieved.
    pub tail_mass: f64,
    /// True when no materialized prefix met `eps` and the order merely
    /// saturated at the materialized length.
    pub saturated: bool,
}

/// A materialized prefix of a kernel's Maclaurin expansion plus the
/// derived quantities the Random Maclaurin construction needs.
#[derive(Clone, Debug)]
pub struct MaclaurinSeries {
    /// Coefficients `a_0 .. a_{n_max}`.
    pub coeffs: Vec<f64>,
    /// `f(R²)` — total series mass at the domain boundary.
    pub total_mass: f64,
    /// Domain bound `R` (data confined to `B_1(0, R)`).
    pub r: f64,
}

impl MaclaurinSeries {
    /// Materialize the first `n_max + 1` coefficients of `kernel` and the
    /// mass bookkeeping at radius `r`.
    pub fn materialize(kernel: &dyn DotProductKernel, n_max: u32, r: f64) -> Self {
        let coeffs: Vec<f64> = (0..=n_max).map(|n| kernel.coeff(n)).collect();
        MaclaurinSeries { coeffs, total_mass: kernel.f(r * r), r }
    }

    /// Mass of the prefix `Σ_{n ≤ k} a_n R^{2n}`.
    pub fn prefix_mass(&self, k: u32) -> f64 {
        let r2 = self.r * self.r;
        let mut pow = 1.0;
        let mut acc = 0.0;
        for (n, &a) in self.coeffs.iter().enumerate() {
            if n as u32 > k {
                break;
            }
            acc += a * pow;
            pow *= r2;
        }
        acc
    }

    /// Tail mass `Σ_{n > k} a_n R^{2n} = f(R²) − prefix(k)` — the uniform
    /// truncation error bound of §4.2.
    pub fn tail_mass(&self, k: u32) -> f64 {
        (self.total_mass - self.prefix_mass(k)).max(0.0)
    }

    /// The §4.2 truncation decision: the smallest order whose residual
    /// bound `Σ_{n>k} a_n R^{2n}` meets `eps`, together with the tail
    /// mass actually achieved and whether the bound was met at all.
    /// When no materialized prefix reaches `eps` the result saturates at
    /// the materialized length with `saturated = true` — the caller can
    /// see the bound was missed instead of silently trusting `n_max`.
    pub fn truncation(&self, eps: f64) -> Truncation {
        let n_max = (self.coeffs.len() - 1) as u32;
        for k in 0..=n_max {
            let tail = self.tail_mass(k);
            if tail <= eps {
                return Truncation { order: k, tail_mass: tail, saturated: false };
            }
        }
        Truncation { order: n_max, tail_mass: self.tail_mass(n_max), saturated: true }
    }

    /// Smallest truncation order `k` such that the §4.2 residual bound
    /// `Σ_{n>k} a_n R^{2n} ≤ eps`, capped at the materialized length.
    /// **Note:** when the bound is unreachable this returns `n_max`
    /// *without* meeting `eps`; use [`MaclaurinSeries::truncation`] to
    /// observe the achieved tail mass and the saturation flag.
    pub fn truncation_order(&self, eps: f64) -> u32 {
        self.truncation(eps).order
    }

    /// True if every materialized coefficient is non-negative —
    /// Schoenberg's positive definiteness condition (Theorem 1).
    pub fn is_positive_definite(&self) -> bool {
        self.coeffs.iter().all(|&a| a >= 0.0)
    }

    /// Largest materialized order with a strictly positive coefficient.
    pub fn last_nonzero_order(&self) -> Option<u32> {
        self.coeffs
            .iter()
            .rposition(|&a| a > 0.0)
            .map(|n| n as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Polynomial};

    #[test]
    fn binomial_small_values() {
        assert_eq!(binomial(5, 2), 10.0);
        assert_eq!(binomial(10, 0), 1.0);
        assert_eq!(binomial(10, 10), 1.0);
        assert_eq!(binomial(3, 7), 0.0);
        assert_eq!(binomial(52, 5), 2_598_960.0);
    }

    #[test]
    fn prefix_plus_tail_is_total() {
        let k = Exponential::new(1.0);
        let s = MaclaurinSeries::materialize(&k, 40, 1.0);
        for cut in [0u32, 3, 10, 40] {
            let sum = s.prefix_mass(cut) + s.tail_mass(cut);
            assert!((sum - s.total_mass).abs() < 1e-9, "cut={cut}");
        }
    }

    #[test]
    fn truncation_order_meets_eps() {
        let k = Exponential::new(1.0);
        let s = MaclaurinSeries::materialize(&k, 60, 1.0);
        let order = s.truncation_order(1e-6);
        assert!(s.tail_mass(order) <= 1e-6);
        assert!(order > 1 && order < 30, "order={order}");
        // Stricter eps needs a larger order.
        assert!(s.truncation_order(1e-12) >= order);
    }

    #[test]
    fn unreachable_eps_is_reported_as_saturated() {
        // Regression: truncation_order used to return n_max as if the
        // bound were met whenever eps was unreachable. The structured
        // result must expose the miss.
        let k = Exponential::new(1.0);
        // Only 5 coefficients materialized: the e^t tail at R=1 cannot
        // get anywhere near 1e-30.
        let s = MaclaurinSeries::materialize(&k, 5, 1.0);
        let t = s.truncation(1e-30);
        assert!(t.saturated, "bound is unreachable, must be flagged");
        assert_eq!(t.order, 5);
        assert!(t.tail_mass > 1e-30, "achieved tail {}", t.tail_mass);
        assert!((t.tail_mass - s.tail_mass(5)).abs() < 1e-15);
        // Compat shim still saturates at n_max.
        assert_eq!(s.truncation_order(1e-30), 5);
        // A reachable eps is not flagged and meets the bound.
        let ok = s.truncation(1.0);
        assert!(!ok.saturated);
        assert!(ok.tail_mass <= 1.0);
    }

    #[test]
    fn polynomial_series_is_finite() {
        let k = Polynomial::new(10, 1.0);
        let s = MaclaurinSeries::materialize(&k, 20, 1.0);
        assert_eq!(s.last_nonzero_order(), Some(10));
        assert!(s.is_positive_definite());
        // Exact: tail after order 10 is zero.
        assert!(s.tail_mass(10).abs() < 1e-6 * s.total_mass);
        assert_eq!(s.truncation_order(0.0), 10);
    }
}
