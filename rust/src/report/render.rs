//! Rendering and (de)serialization of the assembled [`Report`].
//!
//! Three outputs, all pure functions of the result set so regeneration
//! from a cached run-log is byte-identical:
//!
//! * [`report_json`] / [`decode_report`] — the machine-readable
//!   `REPORT.json` and its schema decoder (the drift gate);
//! * [`report_markdown`] — the human `REPORT.md`, with the SVG assets
//!   of [`build_assets`] embedded as images;
//! * [`runlog_json`] / [`parse_runlog`] — the resumable run-log.

use super::svg::{self, Series};
use super::{
    AccuracyRow, Cell, CellStats, CellStatus, Family, Report, RowOutcome, RunLog, ServePoint,
    StageSecs, ThreadPoint, FAMILIES, REPORT_VERSION,
};
use crate::bench::{fmt_duration, Table};
use crate::config::json::Json;
use crate::config::ReportConfig;
use crate::metrics::Summary;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------- encode

/// Build a JSON object from (key, value) pairs.
fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn int(v: usize) -> Json {
    Json::Num(v as f64)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| int(x)).collect())
}

fn summary_json(x: &Summary) -> Json {
    obj(vec![
        ("n", int(x.n)),
        ("mean", num(x.mean)),
        ("min", num(x.min)),
        ("p50", num(x.p50)),
        ("p90", num(x.p90)),
        ("max", num(x.max)),
    ])
}

fn stages_json(st: &StageSecs) -> Json {
    obj(vec![
        ("sample_s", num(st.sample_s)),
        ("gram_s", num(st.gram_s)),
        ("transform_s", num(st.transform_s)),
    ])
}

fn cell_json(c: &Cell) -> Json {
    let mut fields = vec![
        ("id", s(&c.id)),
        ("family", s(&c.family)),
        ("kernel", s(&c.kernel)),
        ("projection", s(&c.projection)),
        ("storage", s(&c.storage)),
        ("d", int(c.d)),
    ];
    match &c.status {
        CellStatus::Ok(stats) => {
            fields.push(("status", s("ok")));
            fields.push(("output_dim", int(stats.output_dim)));
            fields.push(("err", summary_json(&stats.err)));
            fields.push(("secs_per_vec", num(stats.secs_per_vec)));
            fields.push(("stages", stages_json(&stats.stages)));
        }
        CellStatus::Skipped { reason } => {
            fields.push(("status", s("skipped")));
            fields.push(("reason", s(reason)));
        }
    }
    obj(fields)
}

fn accuracy_json(r: &AccuracyRow) -> Json {
    let mut fields = vec![
        ("dataset", s(&r.dataset)),
        ("kernel", s(&r.kernel)),
        ("variant", s(&r.variant)),
    ];
    match &r.outcome {
        RowOutcome::Ok { accuracy, train_s, test_s, size } => {
            fields.push(("status", s("ok")));
            fields.push(("accuracy", num(*accuracy)));
            fields.push(("train_s", num(*train_s)));
            fields.push(("test_s", num(*test_s)));
            fields.push(("size", int(*size)));
        }
        RowOutcome::Skipped { reason } => {
            fields.push(("status", s("skipped")));
            fields.push(("reason", s(reason)));
        }
    }
    obj(fields)
}

fn thread_json(t: &ThreadPoint) -> Json {
    obj(vec![
        ("threads", int(t.threads)),
        ("secs", num(t.secs)),
        ("speedup", num(t.speedup)),
    ])
}

fn serve_json(p: &ServePoint) -> Json {
    obj(vec![
        ("workers", int(p.workers)),
        ("shards", int(p.shards)),
        ("reqs_per_s", num(p.reqs_per_s)),
        ("p50_us", num(p.p50_us)),
        ("p90_us", num(p.p90_us)),
        ("steals", int(p.steals as usize)),
    ])
}

fn grid_json(c: &ReportConfig) -> Json {
    obj(vec![
        ("quick", Json::Bool(c.quick)),
        ("dim", int(c.dim)),
        ("points", int(c.points)),
        ("runs", int(c.runs)),
        ("d_sweep", usize_arr(&c.d_sweep)),
        ("kernels", str_arr(&c.kernels)),
        ("threads_sweep", usize_arr(&c.threads_sweep)),
        ("datasets", str_arr(&c.datasets)),
        ("scale", num(c.scale)),
        ("accuracy_features", int(c.accuracy_features)),
        ("serve_requests", int(c.serve_requests)),
    ])
}

/// Sum the per-stage wall-clock over live cells, alongside the
/// ok/skipped split — the raw material of the v4 `metrics` section.
fn stage_totals(report: &Report) -> (usize, usize, StageSecs) {
    let (mut ok, mut skipped) = (0, 0);
    let mut total = StageSecs::default();
    for c in &report.cells {
        match &c.status {
            CellStatus::Ok(stats) => {
                ok += 1;
                total.sample_s += stats.stages.sample_s;
                total.gram_s += stats.stages.gram_s;
                total.transform_s += stats.stages.transform_s;
            }
            CellStatus::Skipped { .. } => skipped += 1,
        }
    }
    (ok, skipped, total)
}

/// The v4 `metrics` section: a deterministic aggregate over the grid's
/// cells. Derived data only — it is a pure function of the cell set
/// (summed in declaration order, never live process state), so
/// re-rendering from a cached run-log stays byte-identical and
/// [`decode_report`] can verify it by recomputation.
fn metrics_json(report: &Report) -> Json {
    let (ok, skipped, total) = stage_totals(report);
    obj(vec![
        ("cells_ok", int(ok)),
        ("cells_skipped", int(skipped)),
        (
            "stage_secs",
            obj(vec![
                ("sample", num(total.sample_s)),
                ("gram", num(total.gram_s)),
                ("transform", num(total.transform_s)),
                ("total", num(total.sample_s + total.gram_s + total.transform_s)),
            ]),
        ),
    ])
}

/// The full `REPORT.json` document (wrapped in a top-level `"report"`
/// object so the format is self-identifying).
pub fn report_json(report: &Report, assets: &[String]) -> Json {
    obj(vec![(
        "report",
        obj(vec![
            ("version", int(report.version as usize)),
            ("mode", s(&report.mode)),
            // A string, not a JSON number: u64 seeds above 2^53 would
            // silently round through f64 and disagree with the exact
            // seed recorded inside the fingerprint.
            ("seed", s(&report.seed.to_string())),
            ("simd", s(&report.simd)),
            ("fingerprint", s(&report.fingerprint)),
            ("generated_by", s("rfdot report")),
            ("grid", grid_json(&report.config)),
            ("metrics", metrics_json(report)),
            ("cells", Json::Arr(report.cells.iter().map(cell_json).collect())),
            ("accuracy", Json::Arr(report.accuracy.iter().map(accuracy_json).collect())),
            ("threads", Json::Arr(report.threads.iter().map(thread_json).collect())),
            ("serving", Json::Arr(report.serving.iter().map(serve_json).collect())),
            ("assets", str_arr(assets)),
        ]),
    )])
}

/// The resumable run-log document.
pub fn runlog_json(log: &RunLog) -> Json {
    let cells: BTreeMap<String, Json> =
        log.cells.iter().map(|(k, v)| (k.clone(), cell_json(v))).collect();
    let mut fields = vec![("fingerprint", s(&log.fingerprint)), ("cells", Json::Obj(cells))];
    if let Some(rows) = &log.accuracy {
        fields.push(("accuracy", Json::Arr(rows.iter().map(accuracy_json).collect())));
    }
    if let Some(points) = &log.threads {
        fields.push(("threads", Json::Arr(points.iter().map(thread_json).collect())));
    }
    if let Some(points) = &log.serving {
        fields.push(("serving", Json::Arr(points.iter().map(serve_json).collect())));
    }
    obj(fields)
}

// ---------------------------------------------------------------- decode

fn req_str(v: &Json, k: &str) -> Result<String> {
    v.req(k)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Config(format!("report field {k:?} must be a string")))
}

fn req_f64(v: &Json, k: &str) -> Result<f64> {
    v.req(k)?
        .as_f64()
        .ok_or_else(|| Error::Config(format!("report field {k:?} must be a number")))
}

fn req_usize(v: &Json, k: &str) -> Result<usize> {
    v.req(k)?
        .as_usize()
        .ok_or_else(|| Error::Config(format!("report field {k:?} must be a non-negative int")))
}

fn req_arr<'a>(v: &'a Json, k: &str) -> Result<&'a [Json]> {
    v.req(k)?
        .as_arr()
        .ok_or_else(|| Error::Config(format!("report field {k:?} must be an array")))
}

fn decode_summary(v: &Json) -> Result<Summary> {
    Ok(Summary {
        n: req_usize(v, "n")?,
        mean: req_f64(v, "mean")?,
        min: req_f64(v, "min")?,
        p50: req_f64(v, "p50")?,
        p90: req_f64(v, "p90")?,
        max: req_f64(v, "max")?,
    })
}

/// v4 stage breakdown. `strict` (REPORT.json, the drift gate) requires
/// the object; the run-log decoder passes `strict = false` so a pre-v4
/// log still resumes — absent stages read as zero, and the fingerprint
/// (not these fields) decides whether cached cells are reused.
fn decode_stages(v: Option<&Json>, strict: bool) -> Result<StageSecs> {
    match v {
        Some(v) => Ok(StageSecs {
            sample_s: req_f64(v, "sample_s")?,
            gram_s: req_f64(v, "gram_s")?,
            transform_s: req_f64(v, "transform_s")?,
        }),
        None if strict => Err(Error::Config("ok cells must carry a stages breakdown".into())),
        None => Ok(StageSecs::default()),
    }
}

fn decode_cell(v: &Json, strict: bool) -> Result<Cell> {
    let family = req_str(v, "family")?;
    Family::parse(&family)?;
    let status = match req_str(v, "status")?.as_str() {
        "ok" => CellStatus::Ok(CellStats {
            output_dim: req_usize(v, "output_dim")?,
            err: decode_summary(v.req("err")?)?,
            secs_per_vec: req_f64(v, "secs_per_vec")?,
            stages: decode_stages(v.get("stages"), strict)?,
        }),
        "skipped" => {
            let reason = req_str(v, "reason")?;
            if reason.is_empty() {
                return Err(Error::Config("skipped cells must carry a reason".into()));
            }
            CellStatus::Skipped { reason }
        }
        other => {
            return Err(Error::Config(format!(
                "cell status must be \"ok\" or \"skipped\", got {other:?}"
            )))
        }
    };
    Ok(Cell {
        id: req_str(v, "id")?,
        family,
        kernel: req_str(v, "kernel")?,
        projection: req_str(v, "projection")?,
        storage: req_str(v, "storage")?,
        d: req_usize(v, "d")?,
        status,
    })
}

fn decode_accuracy(v: &Json) -> Result<AccuracyRow> {
    let outcome = match req_str(v, "status")?.as_str() {
        "ok" => RowOutcome::Ok {
            accuracy: req_f64(v, "accuracy")?,
            train_s: req_f64(v, "train_s")?,
            test_s: req_f64(v, "test_s")?,
            size: req_usize(v, "size")?,
        },
        "skipped" => RowOutcome::Skipped { reason: req_str(v, "reason")? },
        other => {
            return Err(Error::Config(format!(
                "accuracy status must be \"ok\" or \"skipped\", got {other:?}"
            )))
        }
    };
    Ok(AccuracyRow {
        dataset: req_str(v, "dataset")?,
        kernel: req_str(v, "kernel")?,
        variant: req_str(v, "variant")?,
        outcome,
    })
}

fn decode_thread(v: &Json) -> Result<ThreadPoint> {
    Ok(ThreadPoint {
        threads: req_usize(v, "threads")?,
        secs: req_f64(v, "secs")?,
        speedup: req_f64(v, "speedup")?,
    })
}

fn decode_serve(v: &Json) -> Result<ServePoint> {
    Ok(ServePoint {
        workers: req_usize(v, "workers")?,
        shards: req_usize(v, "shards")?,
        reqs_per_s: req_f64(v, "reqs_per_s")?,
        p50_us: req_f64(v, "p50_us")?,
        p90_us: req_f64(v, "p90_us")?,
        steals: req_usize(v, "steals")? as u64,
    })
}

fn decode_grid(v: &Json, mode: &str, seed: u64) -> Result<ReportConfig> {
    let quick = v
        .req("quick")?
        .as_bool()
        .ok_or_else(|| Error::Config("grid quick must be a bool".into()))?;
    if quick != (mode == "quick") {
        return Err(Error::Config("grid quick flag disagrees with report mode".into()));
    }
    Ok(ReportConfig {
        quick,
        seed,
        // Output placement is not part of the recorded grid.
        out_dir: ".".into(),
        resume: true,
        dim: req_usize(v, "dim")?,
        points: req_usize(v, "points")?,
        runs: req_usize(v, "runs")?,
        d_sweep: crate::config::usize_list(req_arr(v, "d_sweep")?, "d_sweep")?,
        kernels: crate::config::str_list(req_arr(v, "kernels")?, "kernels")?,
        threads_sweep: crate::config::usize_list(req_arr(v, "threads_sweep")?, "threads_sweep")?,
        datasets: crate::config::str_list(req_arr(v, "datasets")?, "datasets")?,
        scale: req_f64(v, "scale")?,
        accuracy_features: req_usize(v, "accuracy_features")?,
        serve_requests: req_usize(v, "serve_requests")?,
    })
}

/// Decode a parsed `REPORT.json` document into the typed [`Report`],
/// validating the schema version, every status tag and the per-status
/// required fields — the drift gate behind [`super::parse_report`].
pub fn decode_report(doc: &Json) -> Result<Report> {
    let v = doc.req("report")?;
    let version = req_usize(v, "version")? as u64;
    if version != REPORT_VERSION {
        return Err(Error::Config(format!(
            "report schema version {version} != supported {REPORT_VERSION}"
        )));
    }
    let mode = req_str(v, "mode")?;
    if mode != "quick" && mode != "full" {
        return Err(Error::Config(format!("report mode must be quick|full, got {mode:?}")));
    }
    let seed = req_str(v, "seed")?
        .parse::<u64>()
        .map_err(|_| Error::Config("report seed must be a u64 string".into()))?;
    let config = decode_grid(v.req("grid")?, &mode, seed)?;
    let cells = req_arr(v, "cells")?
        .iter()
        .map(|c| decode_cell(c, true))
        .collect::<Result<Vec<_>>>()?;
    let accuracy =
        req_arr(v, "accuracy")?.iter().map(decode_accuracy).collect::<Result<Vec<_>>>()?;
    let threads =
        req_arr(v, "threads")?.iter().map(decode_thread).collect::<Result<Vec<_>>>()?;
    let serving =
        req_arr(v, "serving")?.iter().map(decode_serve).collect::<Result<Vec<_>>>()?;
    // Assets must be declared (the markdown references them).
    crate::config::str_list(req_arr(v, "assets")?, "assets")?;
    let report = Report {
        version,
        mode,
        seed,
        simd: req_str(v, "simd")?,
        fingerprint: req_str(v, "fingerprint")?,
        config,
        cells,
        accuracy,
        threads,
        serving,
    };
    // The v4 metrics section is derived data; recompute it from the
    // decoded cells and require byte-for-byte agreement (an edited
    // document or a drifted encoder both trip here).
    if *v.req("metrics")? != metrics_json(&report) {
        return Err(Error::Config(
            "report metrics section disagrees with the aggregate of its cells".into(),
        ));
    }
    Ok(report)
}

/// Decode a run-log document (tolerant counterpart of [`runlog_json`]:
/// `accuracy`/`threads` may be absent while a run is in flight).
pub fn parse_runlog(text: &str, path: PathBuf) -> Result<RunLog> {
    let doc = Json::parse(text)?;
    let fingerprint = req_str(&doc, "fingerprint")?;
    let mut cells = BTreeMap::new();
    match doc.req("cells")? {
        Json::Obj(map) => {
            for (k, v) in map {
                cells.insert(k.clone(), decode_cell(v, false)?);
            }
        }
        _ => return Err(Error::Config("run-log cells must be an object".into())),
    }
    let accuracy = match doc.get("accuracy") {
        Some(v) => Some(
            v.as_arr()
                .ok_or_else(|| Error::Config("run-log accuracy must be an array".into()))?
                .iter()
                .map(decode_accuracy)
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    let threads = match doc.get("threads") {
        Some(v) => Some(
            v.as_arr()
                .ok_or_else(|| Error::Config("run-log threads must be an array".into()))?
                .iter()
                .map(decode_thread)
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    let serving = match doc.get("serving") {
        Some(v) => Some(
            v.as_arr()
                .ok_or_else(|| Error::Config("run-log serving must be an array".into()))?
                .iter()
                .map(decode_serve)
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    Ok(RunLog { fingerprint, cells, accuracy, threads, serving, path })
}

// ---------------------------------------------------------------- assets

/// Find a live cell's stats by grid coordinates.
fn find_stats<'a>(
    report: &'a Report,
    family: Family,
    kernel: &str,
    projection: &str,
    storage: &str,
    d: usize,
) -> Option<&'a CellStats> {
    report
        .cells
        .iter()
        .find(|c| {
            c.family == family.id()
                && c.kernel == kernel
                && c.projection == projection
                && c.storage == storage
                && c.d == d
        })
        .and_then(|c| match &c.status {
            CellStatus::Ok(stats) => Some(stats),
            CellStatus::Skipped { .. } => None,
        })
}

/// Error-vs-D series for one family: one line per (kernel, projection)
/// with live cells, on dense storage (storage changes cost, never
/// error, by the sparse parity contract).
fn error_series(report: &Report, family: Family) -> Vec<Series> {
    let mut series = Vec::new();
    for kernel in &report.config.kernels {
        for projection in ["dense", "structured"] {
            let points: Vec<(f64, f64)> = report
                .config
                .d_sweep
                .iter()
                .filter_map(|&d| {
                    find_stats(report, family, kernel, projection, "dense", d)
                        .map(|stats| (d as f64, stats.err.mean))
                })
                .collect();
            if !points.is_empty() {
                series.push(Series { label: format!("{kernel} ({projection})"), points });
            }
        }
    }
    series
}

/// Speedup bars for one family, at every D of the sweep: sparse storage
/// vs dense storage, and structured vs dense projection, both measured
/// against the same dense/dense baseline cell (first kernel with a
/// live baseline wins; all kernels share shapes so the cost story is
/// the same).
fn speedup_bars(report: &Report, family: Family) -> Vec<(String, f64)> {
    let mut bars = Vec::new();
    for &d in &report.config.d_sweep {
        for kernel in &report.config.kernels {
            let Some(base) = find_stats(report, family, kernel, "dense", "dense", d) else {
                continue;
            };
            let base_secs = base.secs_per_vec.max(1e-12);
            if let Some(sp) = find_stats(report, family, kernel, "dense", "sparse", d) {
                bars.push((format!("sparse D{d}"), base_secs / sp.secs_per_vec.max(1e-12)));
            }
            if let Some(st) = find_stats(report, family, kernel, "structured", "dense", d) {
                bars.push((format!("structured D{d}"), base_secs / st.secs_per_vec.max(1e-12)));
            }
            break;
        }
    }
    bars
}

/// All SVG assets as `(relative path, content)` pairs: per-family
/// error-vs-D curves and speedup bars, plus the thread-scaling chart.
pub fn build_assets(report: &Report) -> Vec<(String, String)> {
    let mut assets = Vec::new();
    for family in FAMILIES {
        assets.push((
            format!("report/error_{}.svg", family.id()),
            svg::line_chart(
                &format!("{}: gram error vs D (log-log)", family.display()),
                "D (output features)",
                "mean |<Z(x),Z(y)> - K(x,y)|",
                &error_series(report, family),
            ),
        ));
        assets.push((
            format!("report/speedup_{}.svg", family.id()),
            svg::bar_chart(
                &format!("{}: per-input transform speedup vs dense/dense", family.display()),
                "x faster than dense/dense",
                &speedup_bars(report, family),
            ),
        ));
    }
    let thread_bars: Vec<(String, f64)> = report
        .threads
        .iter()
        .map(|t| (format!("{} threads", t.threads), t.speedup))
        .collect();
    assets.push((
        "report/threads.svg".to_string(),
        svg::bar_chart(
            "transform_batch thread scaling (Random Maclaurin)",
            "speedup vs 1 thread",
            &thread_bars,
        ),
    ));
    let serve_bars: Vec<(String, f64)> = report
        .serving
        .iter()
        .map(|p| {
            let topology = if p.shards == 1 { "shared" } else { "sharded" };
            (format!("{}w {topology}", p.workers), p.reqs_per_s)
        })
        .collect();
    assets.push((
        "report/serving.svg".to_string(),
        svg::bar_chart(
            "coordinator throughput: workers x queue topology",
            "requests / second",
            &serve_bars,
        ),
    ));
    assets
}

// -------------------------------------------------------------- markdown

/// Render `REPORT.md` — the human-facing reproduction evidence, with
/// every table derived from the same result set as `REPORT.json` and
/// the assets embedded as images.
pub fn report_markdown(report: &Report, assets: &[String]) -> String {
    let c = &report.config;
    let mut md = String::new();
    md.push_str("# rfdot reproduction report\n\n");
    md.push_str(&format!(
        "> Generated by `rfdot report` (mode: **{}**, seed: {}, simd: {}, schema v{}).\n\
         > Do not edit by hand — rerun `rfdot report{}` to regenerate; the\n\
         > paired `REPORT.json` carries the same data machine-readably.\n\n",
        report.mode,
        report.seed,
        report.simd,
        report.version,
        if report.mode == "quick" { " --quick" } else { "" },
    ));
    md.push_str(
        "The grid below is the paper's evidence regenerated from the current\n\
         code: Kar & Karnick's Figure-1 claim that `<Z(x), Z(y)>` approaches\n\
         `f(<x, y>)` as D grows, the Table-1 claim that random features match\n\
         exact kernel SVMs at a fraction of the cost, and this repo's own\n\
         claims about structured (FWHT) projections, the sparse CSR pipeline\n\
         and the data-parallel thread fan-out.\n\n",
    );

    md.push_str("## Grid\n\n");
    let mut t = Table::new(&["axis", "values"]);
    t.row(&["families".into(), FAMILIES.map(|f| f.id()).join(", ")]);
    t.row(&["kernels".into(), c.kernels.join(", ")]);
    t.row(&["projections".into(), "dense, structured".into()]);
    t.row(&["storage".into(), "dense, sparse (CSR)".into()]);
    t.row(&["D sweep".into(), join_usizes(&c.d_sweep)]);
    t.row(&[
        "gram points".into(),
        format!("{} unit vectors in R^{} (~25% density)", c.points, c.dim),
    ]);
    t.row(&["maps per cell".into(), format!("{}", c.runs)]);
    t.row(&["threads sweep".into(), join_usizes(&c.threads_sweep)]);
    t.row(&["datasets".into(), format!("{} (scale {})", c.datasets.join(", "), c.scale)]);
    md.push_str(&t.render());
    md.push('\n');

    md.push_str("## Kernel approximation error (Figure 1)\n\n");
    md.push_str(
        "Mean absolute Gram error per cell, over independently resampled\n\
         maps (nearest-rank percentiles). Sparse-storage cells are omitted\n\
         here: by the sparse parity contract their errors equal the dense\n\
         ones bit for bit — storage only moves the cost column below.\n\n",
    );
    for family in FAMILIES {
        md.push_str(&format!("### {}\n\n", family.display()));
        md.push_str(&format!("![error vs D](report/error_{}.svg)\n\n", family.id()));
        let mut t = Table::new(&[
            "kernel", "projection", "D", "output dim", "err mean", "err p90", "secs/vec",
        ]);
        let mut live = 0;
        for cell in &report.cells {
            if cell.family != family.id() || cell.storage != "dense" {
                continue;
            }
            if let CellStatus::Ok(stats) = &cell.status {
                t.row(&[
                    cell.kernel.clone(),
                    cell.projection.clone(),
                    format!("{}", cell.d),
                    format!("{}", stats.output_dim),
                    svg::fmt_num(stats.err.mean),
                    svg::fmt_num(stats.err.p90),
                    fmt_duration(stats.secs_per_vec),
                ]);
                live += 1;
            }
        }
        if live > 0 {
            md.push_str(&t.render());
        } else {
            md.push_str("(no applicable cells for this family — see Skipped cells)\n");
        }
        md.push('\n');
    }

    md.push_str("## Transform cost: dense vs structured vs sparse\n\n");
    md.push_str(
        "Per-input batch-transform speedups against each family's\n\
         dense-projection / dense-storage baseline cell (same data, same\n\
         D): the structured bars realize the `O(D log d)` FWHT projections,\n\
         the sparse bars the `O(D nnz)` CSR kernels.\n\n",
    );
    for family in FAMILIES {
        md.push_str(&format!(
            "![{} speedups](report/speedup_{}.svg)\n\n",
            family.display(),
            family.id(),
        ));
    }

    md.push_str("## Accuracy (Table 1)\n\n");
    md.push_str(
        "Exact kernel SVM vs every feature-map family + linear SVM, per\n\
         dataset and kernel (timings include map construction and\n\
         application, the paper's protocol).\n\n",
    );
    let mut t = Table::new(&["dataset", "kernel", "variant", "acc", "trn", "tst", "size", "note"]);
    for row in &report.accuracy {
        match &row.outcome {
            RowOutcome::Ok { accuracy, train_s, test_s, size } => t.row(&[
                row.dataset.clone(),
                row.kernel.clone(),
                row.variant.clone(),
                format!("{:.2}%", accuracy * 100.0),
                fmt_duration(*train_s),
                fmt_duration(*test_s),
                format!("{size}"),
                String::new(),
            ]),
            RowOutcome::Skipped { reason } => t.row(&[
                row.dataset.clone(),
                row.kernel.clone(),
                row.variant.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("skipped: {reason}"),
            ]),
        }
    }
    md.push_str(&t.render());
    md.push('\n');

    md.push_str("## Thread scaling\n\n");
    md.push_str("![thread scaling](report/threads.svg)\n\n");
    let mut t = Table::new(&["threads", "secs/batch", "speedup"]);
    for p in &report.threads {
        t.row(&[
            format!("{}", p.threads),
            fmt_duration(p.secs),
            format!("{:.2}x", p.speedup),
        ]);
    }
    md.push_str(&t.render());
    md.push('\n');

    md.push_str("## Serving throughput\n\n");
    md.push_str(
        "The coordinator under a concurrent client load (native backend),\n\
         swept over worker count and batch-queue topology: `shared` is one\n\
         queue every worker pops from (the pre-shard baseline), `sharded`\n\
         is one queue per worker with work stealing for stragglers.\n\
         Replies are bit-identical across topologies (the serving parity\n\
         contract); only throughput, latency and steal counts move.\n\n",
    );
    md.push_str("![serving throughput](report/serving.svg)\n\n");
    let mut t = Table::new(&["workers", "topology", "req/s", "p50", "p90", "steals"]);
    for p in &report.serving {
        t.row(&[
            format!("{}", p.workers),
            if p.shards == 1 {
                "shared".into()
            } else {
                format!("sharded x{}", p.shards)
            },
            format!("{:.0}", p.reqs_per_s),
            format!("<={:.0}us", p.p50_us),
            format!("<={:.0}us", p.p90_us),
            format!("{}", p.steals),
        ]);
    }
    md.push_str(&t.render());
    md.push('\n');

    md.push_str("## Metrics\n\n");
    md.push_str(
        "Where the grid's wall-clock went, summed over live cells (the\n\
         same v4 breakdown `REPORT.json` carries under `metrics`):\n\
         sampling the random maps, building the feature grams for the\n\
         error envelope, and the timed batch transforms.\n\n",
    );
    let (ok_cells, skipped_cells, totals) = stage_totals(report);
    let mut t = Table::new(&["stage", "total wall-clock"]);
    t.row(&["map sampling".into(), fmt_duration(totals.sample_s)]);
    t.row(&["gram error".into(), fmt_duration(totals.gram_s)]);
    t.row(&["batch transform".into(), fmt_duration(totals.transform_s)]);
    t.row(&[
        "all stages".into(),
        fmt_duration(totals.sample_s + totals.gram_s + totals.transform_s),
    ]);
    md.push_str(&t.render());
    md.push_str(&format!("\n({ok_cells} live cells, {skipped_cells} skipped)\n\n"));

    md.push_str("## Skipped cells\n\n");
    md.push_str(
        "Every declared cell the grid could not run, with its reason —\n\
         nothing is silently dropped.\n\n",
    );
    let mut t = Table::new(&["cell", "reason"]);
    let mut skipped = 0;
    for cell in &report.cells {
        if let CellStatus::Skipped { reason } = &cell.status {
            t.row(&[cell.id.clone(), reason.clone()]);
            skipped += 1;
        }
    }
    if skipped > 0 {
        md.push_str(&t.render());
    } else {
        md.push_str("(none)\n");
    }
    md.push('\n');

    md.push_str("## Assets\n\n");
    for a in assets {
        md.push_str(&format!("- `{a}`\n"));
    }
    md.push_str(&format!(
        "\n<!-- fingerprint: {} -->\n",
        report.fingerprint.replace("--", "- -"),
    ));
    md
}

fn join_usizes(xs: &[usize]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
}

// --------------------------------------------------------------- write

/// Write `REPORT.json`, `REPORT.md` and every SVG asset under
/// `out_dir` (assets under `out_dir/report/`).
pub fn write_all(report: &Report, out_dir: &Path) -> Result<()> {
    let assets = build_assets(report);
    for (rel, content) in &assets {
        std::fs::write(out_dir.join(rel), content)?;
    }
    let names: Vec<String> = assets.iter().map(|(n, _)| n.clone()).collect();
    std::fs::write(out_dir.join("REPORT.json"), report_json(report, &names).pretty())?;
    std::fs::write(out_dir.join("REPORT.md"), report_markdown(report, &names))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> Report {
        let mut config = ReportConfig::quick();
        config.kernels = vec!["poly:3:1".into()];
        config.d_sweep = vec![16];
        let ok = Cell {
            id: "rm|poly:3:1|dense|dense|D16".into(),
            family: "rm".into(),
            kernel: "poly:3:1".into(),
            projection: "dense".into(),
            storage: "dense".into(),
            d: 16,
            status: CellStatus::Ok(CellStats {
                output_dim: 16,
                err: Summary::from_samples(&[0.5, 0.3]),
                secs_per_vec: 1.5e-6,
                stages: StageSecs { sample_s: 0.5, gram_s: 0.25, transform_s: 0.125 },
            }),
        };
        let sparse = Cell {
            id: "rm|poly:3:1|dense|sparse|D16".into(),
            storage: "sparse".into(),
            status: CellStatus::Ok(CellStats {
                output_dim: 16,
                err: Summary::from_samples(&[0.5, 0.3]),
                secs_per_vec: 0.5e-6,
                stages: StageSecs { sample_s: 0.5, gram_s: 0.125, transform_s: 0.0625 },
            }),
            ..ok.clone()
        };
        let skipped = Cell {
            id: "rff|poly:3:1|dense|dense|D16".into(),
            family: "rff".into(),
            status: CellStatus::Skipped { reason: "not shift-invariant".into() },
            ..ok.clone()
        };
        Report {
            version: REPORT_VERSION,
            mode: "quick".into(),
            seed: 42,
            simd: "scalar".into(),
            fingerprint: config.fingerprint(),
            config,
            cells: vec![ok, sparse, skipped],
            accuracy: vec![
                AccuracyRow {
                    dataset: "nursery".into(),
                    kernel: "poly:3:1".into(),
                    variant: "K+SMO".into(),
                    outcome: RowOutcome::Ok {
                        accuracy: 0.9,
                        train_s: 1.0,
                        test_s: 0.5,
                        size: 100,
                    },
                },
                AccuracyRow {
                    dataset: "nursery".into(),
                    kernel: "poly:3:1".into(),
                    variant: "RFF+LIN".into(),
                    outcome: RowOutcome::Skipped { reason: "exponential kernels only".into() },
                },
            ],
            threads: vec![
                ThreadPoint { threads: 1, secs: 1.0, speedup: 1.0 },
                ThreadPoint { threads: 2, secs: 0.6, speedup: 1.667 },
            ],
            serving: vec![
                ServePoint {
                    workers: 2,
                    shards: 1,
                    reqs_per_s: 5000.0,
                    p50_us: 128.0,
                    p90_us: 512.0,
                    steals: 0,
                },
                ServePoint {
                    workers: 2,
                    shards: 2,
                    reqs_per_s: 8000.0,
                    p50_us: 64.0,
                    p90_us: 256.0,
                    steals: 3,
                },
            ],
        }
    }

    #[test]
    fn report_json_round_trips_through_decode() {
        let report = tiny_report();
        let doc = report_json(&report, &["report/error_rm.svg".into()]);
        let text = doc.pretty();
        let back = decode_report(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cells.len(), 3);
        assert_eq!(back.mode, "quick");
        assert_eq!(back.seed, 42);
        assert_eq!(back.fingerprint, report.fingerprint);
        assert_eq!(back.config.d_sweep, vec![16]);
        match &back.cells[0].status {
            CellStatus::Ok(stats) => {
                assert_eq!(stats.output_dim, 16);
                assert_eq!(stats.err.n, 2);
                assert!((stats.err.mean - 0.4).abs() < 1e-12);
                // The v4 stage breakdown survives the round trip exactly
                // (the fixture's powers of two have exact JSON forms).
                assert_eq!(
                    stats.stages,
                    StageSecs { sample_s: 0.5, gram_s: 0.25, transform_s: 0.125 },
                );
            }
            CellStatus::Skipped { .. } => panic!("cell 0 must be ok"),
        }
        // The metrics section is present and aggregates the live cells.
        let metrics = doc.req("report").unwrap().req("metrics").unwrap();
        assert_eq!(metrics.req("cells_ok").unwrap().as_usize(), Some(2));
        assert_eq!(metrics.req("cells_skipped").unwrap().as_usize(), Some(1));
        let stage_secs = metrics.req("stage_secs").unwrap();
        assert_eq!(stage_secs.req("sample").unwrap().as_f64(), Some(1.0));
        assert_eq!(stage_secs.req("transform").unwrap().as_f64(), Some(0.1875));
        match &back.cells[2].status {
            CellStatus::Skipped { reason } => assert_eq!(reason, "not shift-invariant"),
            CellStatus::Ok(_) => panic!("cell 2 must be skipped"),
        }
        // Encoding is deterministic.
        assert_eq!(text, report_json(&report, &["report/error_rm.svg".into()]).pretty());

        // Seeds above 2^53 survive the round-trip exactly (they travel
        // as strings, not JSON numbers).
        let mut big = tiny_report();
        big.seed = (1u64 << 53) + 1;
        let redecoded =
            decode_report(&Json::parse(&report_json(&big, &[]).pretty()).unwrap()).unwrap();
        assert_eq!(redecoded.seed, (1u64 << 53) + 1);
    }

    #[test]
    fn decode_rejects_drift() {
        let report = tiny_report();
        let good = report_json(&report, &[]).pretty();
        // Version bump = drift.
        let bad = good.replace(
            &format!("\"version\": {REPORT_VERSION}"),
            &format!("\"version\": {}", REPORT_VERSION + 1),
        );
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // A missing serving panel = drift (the v2 section is required).
        let bad = good.replace("\"serving\"", "\"serving_panel\"");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // Unknown status tag = drift.
        let bad = good.replace("\"status\": \"skipped\"", "\"status\": \"pending\"");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // A skipped cell without a reason = drift.
        let bad = good.replace("\"reason\": \"not shift-invariant\"", "\"reason\": \"\"");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // A missing metrics section = drift (the v4 section is required).
        let bad = good.replace("\"metrics\"", "\"metrics_panel\"");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // Ok cells without the v4 stage breakdown = drift.
        let bad = good.replace("\"stages\"", "\"stage_breakdown\"");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
        // A tampered aggregate (metrics disagreeing with its cells) = drift.
        let bad = good.replace("\"cells_ok\": 2", "\"cells_ok\": 3");
        assert!(decode_report(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn runlog_round_trips_and_tolerates_partial_logs() {
        let report = tiny_report();
        let mut cells = BTreeMap::new();
        for c in &report.cells {
            cells.insert(c.id.clone(), c.clone());
        }
        let log = RunLog {
            fingerprint: "fp".into(),
            cells,
            accuracy: None,
            threads: Some(report.threads.clone()),
            serving: Some(report.serving.clone()),
            path: PathBuf::from("/tmp/x"),
        };
        let text = runlog_json(&log).pretty();
        let back = parse_runlog(&text, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(back.fingerprint, "fp");
        assert_eq!(back.cells.len(), 3);
        assert!(back.accuracy.is_none());
        assert_eq!(back.threads.as_ref().map(Vec::len), Some(2));
        let serving = back.serving.as_ref().expect("serving points survive the round trip");
        assert_eq!(serving.len(), 2);
        assert_eq!(serving[1].shards, 2);
        assert_eq!(serving[1].steals, 3);

        // A pre-v4 run-log (no per-cell stage breakdown) still loads:
        // absent stages decode as zero rather than invalidating the log.
        fn strip_stages(j: &mut Json) {
            match j {
                Json::Obj(m) => {
                    m.remove("stages");
                    for v in m.values_mut() {
                        strip_stages(v);
                    }
                }
                Json::Arr(xs) => xs.iter_mut().for_each(strip_stages),
                _ => {}
            }
        }
        let mut old = runlog_json(&log);
        strip_stages(&mut old);
        let back = parse_runlog(&old.pretty(), PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(back.cells.len(), 3);
        let live = back
            .cells
            .values()
            .find_map(|c| match &c.status {
                CellStatus::Ok(stats) => Some(stats),
                CellStatus::Skipped { .. } => None,
            })
            .expect("fixture has live cells");
        assert_eq!(live.stages, StageSecs::default());
    }

    #[test]
    fn markdown_contains_every_section_and_skip() {
        let report = tiny_report();
        let assets: Vec<String> = build_assets(&report).into_iter().map(|(n, _)| n).collect();
        let md = report_markdown(&report, &assets);
        for section in [
            "# rfdot reproduction report",
            "## Grid",
            "## Kernel approximation error (Figure 1)",
            "## Transform cost: dense vs structured vs sparse",
            "## Accuracy (Table 1)",
            "## Thread scaling",
            "## Serving throughput",
            "## Metrics",
            "## Skipped cells",
        ] {
            assert!(md.contains(section), "missing {section:?}");
        }
        assert!(md.contains("sharded x2"), "serving table must label the sharded topology");
        assert!(md.contains("(2 live cells, 1 skipped)"), "metrics section must count cells");
        assert!(md.contains("not shift-invariant"));
        assert!(md.contains("report/error_rm.svg"));
        assert!(md.contains("90.00%"));
        // Deterministic rendering.
        assert_eq!(md, report_markdown(&report, &assets));
    }

    #[test]
    fn assets_cover_every_family() {
        let report = tiny_report();
        let assets = build_assets(&report);
        for family in FAMILIES {
            assert!(assets.iter().any(|(n, _)| n.contains(&format!("error_{}", family.id()))));
            assert!(
                assets.iter().any(|(n, _)| n.contains(&format!("speedup_{}", family.id())))
            );
        }
        assert!(assets.iter().any(|(n, _)| n.ends_with("threads.svg")));
        assert!(assets.iter().any(|(n, _)| n.ends_with("serving.svg")));
        // The rm speedup chart sees the 3x sparse win of the tiny report.
        let (_, rm_speedup) =
            assets.iter().find(|(n, _)| n.contains("speedup_rm")).unwrap();
        assert!(rm_speedup.contains("3.00x"), "sparse bar should read 3.00x");
    }
}
