//! The self-documenting reproduction-report subsystem (`rfdot report`).
//!
//! Everything PRs 1–3 built — the [`crate::features`] map families, the
//! [`crate::structured`] projections, the sparse CSR pipeline and the
//! [`crate::parallel`] thread fan-out — unified under one driver that
//! *generates* the repo's evidence instead of hand-writing it:
//!
//! 1. **The grid is data.** [`grid`] declares the full cross product
//!    feature-map family × kernel × projection × storage × D
//!    ([`CellSpec`]); [`skip_reason`] marks inapplicable combinations.
//!    Nothing is silently dropped: every requested cell appears in the
//!    output as `ok` or `skipped` with a reason.
//! 2. **Execution is resumable.** Results stream into a JSON run-log
//!    ([`RunLog`], written after every finished cell) keyed by the
//!    config fingerprint, so an interrupted full-grid run resumes where
//!    it stopped and `report --quick` stays CI-sized.
//! 3. **Rendering is reproducible.** [`run`] assembles a typed
//!    [`Report`] and [`render`] writes `REPORT.json`, `REPORT.md` and
//!    the `report/*.svg` assets ([`svg`]) as pure functions of the
//!    result set — regenerating from the same run-log is byte-identical
//!    (`rust/tests/report_schema.rs`), and the seed-deterministic
//!    fields (gram errors, accuracies) agree across fresh runs because
//!    every cell derives its RNG stream from
//!    `seed ^ fnv1a(cell seed_key)`, independent of execution order
//!    (and of the storage axis — dense/sparse twin cells sample the
//!    same maps, so their error envelopes are equal by the sparse
//!    parity contract).
//!
//! The measured quantities are the paper's: per-cell mean absolute Gram
//! error `|⟨Z(x), Z(y)⟩ − K(x, y)|` (Kar & Karnick Figure 1, summarized
//! by [`crate::metrics::Summary`] percentiles over resampled maps),
//! Table-1-style accuracy rows through
//! [`crate::bench::experiment::run_variant`], and per-input transform
//! latency with the dense-vs-structured-vs-sparse speedups the later
//! PRs target.

pub mod render;
pub mod svg;

use crate::bench::experiment::{self, MapVariant};
use crate::config::json::Json;
use crate::config::{ExperimentConfig, KernelSpec, ReportConfig};
use crate::features::FeatureMap;
use crate::kernels::DotProductKernel;
use crate::linalg::{Matrix, SparseMatrix};
use crate::maclaurin::{RandomMaclaurin, RmConfig};
use crate::metrics::Summary;
use crate::nystrom::Nystrom;
use crate::rff::{rbf, RandomFourier};
use crate::rng::Rng;
use crate::structured::ProjectionKind;
use crate::tensorsketch::TensorSketch;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Schema version stamped into `REPORT.json` (bump on layout changes;
/// [`parse_report`] rejects documents from another version, which is
/// what the CI smoke's "schema drift" gate trips on). v2 added the
/// serving-throughput panel (`serving` section); v3 added the `simd`
/// axis (which kernel-dispatch path the grid ran on); v4 added the
/// per-cell `stages` wall-clock breakdown and the aggregated
/// `metrics` section.
pub const REPORT_VERSION: u64 = 4;

/// The feature-map families of the grid, in declaration order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Random Maclaurin (the paper's Algorithm 1).
    Maclaurin,
    /// Random Maclaurin with the H0/1 heuristic (§6.1).
    MaclaurinH01,
    /// Random Fourier features (Rahimi & Recht) — the paper's main
    /// comparison, applicable to exponential kernels on the sphere.
    Fourier,
    /// TensorSketch (Pham & Pagh) — polynomial kernels only.
    TensorSketch,
    /// Nyström landmarks — the data-dependent baseline.
    Nystrom,
}

/// Every family, in the order cells are declared and rendered.
pub const FAMILIES: [Family; 5] = [
    Family::Maclaurin,
    Family::MaclaurinH01,
    Family::Fourier,
    Family::TensorSketch,
    Family::Nystrom,
];

impl Family {
    /// Stable id used in cell ids, JSON and asset file names.
    pub fn id(&self) -> &'static str {
        match self {
            Family::Maclaurin => "rm",
            Family::MaclaurinH01 => "rm-h01",
            Family::Fourier => "rff",
            Family::TensorSketch => "tensorsketch",
            Family::Nystrom => "nystrom",
        }
    }

    /// Human name for the rendered report.
    pub fn display(&self) -> &'static str {
        match self {
            Family::Maclaurin => "Random Maclaurin",
            Family::MaclaurinH01 => "Random Maclaurin + H0/1",
            Family::Fourier => "Random Fourier",
            Family::TensorSketch => "TensorSketch",
            Family::Nystrom => "Nystrom",
        }
    }

    /// Inverse of [`Family::id`] (schema decoding).
    pub fn parse(s: &str) -> Result<Family> {
        FAMILIES
            .into_iter()
            .find(|f| f.id() == s)
            .ok_or_else(|| Error::Config(format!("unknown feature-map family {s:?}")))
    }
}

/// Which storage a cell routes its inputs through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageKind {
    Dense,
    Sparse,
}

impl StorageKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            StorageKind::Dense => "dense",
            StorageKind::Sparse => "sparse",
        }
    }

    pub fn parse(s: &str) -> Result<StorageKind> {
        match s {
            "dense" => Ok(StorageKind::Dense),
            "sparse" => Ok(StorageKind::Sparse),
            other => Err(Error::Config(format!("unknown storage {other:?}"))),
        }
    }
}

/// One requested grid cell (an element of the declared cross product).
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub family: Family,
    /// Kernel in CLI spelling (`poly:10:1`, ...).
    pub kernel: String,
    pub projection: ProjectionKind,
    pub storage: StorageKind,
    /// Target output dimension D (families may round: TensorSketch
    /// pads to a power of two, H0/1 prepends `1 + d` exact terms — the
    /// realized width is recorded per cell as `output_dim`).
    pub d: usize,
}

impl CellSpec {
    /// Stable id: the run-log key and the JSON `id` field.
    pub fn id(&self) -> String {
        format!(
            "{}|{}|{}|{}|D{}",
            self.family.id(),
            self.kernel,
            self.projection.as_str(),
            self.storage.as_str(),
            self.d
        )
    }

    /// Label of the cell's RNG stream — [`CellSpec::id`] *without* the
    /// storage axis, so a sparse cell samples exactly the maps of its
    /// dense twin. That makes the sparse parity contract visible in
    /// the report itself: dense/sparse twin cells carry equal error
    /// envelopes and differ only in the cost column (pinned by
    /// `rust/tests/report_schema.rs`).
    pub fn seed_key(&self) -> String {
        format!(
            "{}|{}|{}|D{}",
            self.family.id(),
            self.kernel,
            self.projection.as_str(),
            self.d
        )
    }
}

/// Declare the full experimental grid for a config — as data, before
/// anything runs. [`run`] executes exactly this list and the schema
/// test pins that the output contains exactly these ids.
pub fn grid(config: &ReportConfig) -> Vec<CellSpec> {
    let mut cells = Vec::new();
    for family in FAMILIES {
        for kernel in &config.kernels {
            for projection in [ProjectionKind::Dense, ProjectionKind::Structured] {
                for storage in [StorageKind::Dense, StorageKind::Sparse] {
                    for &d in &config.d_sweep {
                        cells.push(CellSpec {
                            family,
                            kernel: kernel.clone(),
                            projection,
                            storage,
                            d,
                        });
                    }
                }
            }
        }
    }
    cells
}

/// Why a declared cell cannot run, if it cannot. The grid is an honest
/// cross product: combinations a family does not support are rendered
/// as explicit `skipped` entries carrying this reason, never dropped.
pub fn skip_reason(spec: &CellSpec, kernel: &KernelSpec) -> Option<String> {
    match spec.family {
        Family::Maclaurin => None,
        Family::MaclaurinH01 => {
            let k = kernel.build(1.0);
            if k.coeff(0) > 0.0 || k.coeff(1) > 0.0 {
                None
            } else {
                Some(
                    "H0/1 needs a_0 > 0 or a_1 > 0 (homogeneous kernels have neither)"
                        .into(),
                )
            }
        }
        Family::Fourier => {
            if matches!(kernel, KernelSpec::Exponential { .. }) {
                None
            } else {
                Some(
                    "random Fourier features target shift-invariant kernels; only the \
                     exponential kernel coincides with an RBF on the unit sphere"
                        .into(),
                )
            }
        }
        Family::TensorSketch => {
            if !matches!(
                kernel,
                KernelSpec::Polynomial { .. } | KernelSpec::Homogeneous { .. }
            ) {
                Some("tensorsketch sketches fixed-degree polynomial kernels only".into())
            } else if spec.projection == ProjectionKind::Structured {
                Some("tensorsketch has no projection stack; --projection does not apply".into())
            } else {
                None
            }
        }
        Family::Nystrom => {
            if spec.projection == ProjectionKind::Structured {
                Some(
                    "nystrom features are kernel evaluations against landmarks; \
                     no projection stack"
                        .into(),
                )
            } else {
                None
            }
        }
    }
}

/// Measured statistics of one live cell.
#[derive(Clone, Debug)]
pub struct CellStats {
    /// Realized output dimension (D after family-specific rounding).
    pub output_dim: usize,
    /// Mean |⟨Z(x), Z(y)⟩ − K(x, y)| per resampled map (the Figure 1
    /// metric), summarized over `runs` independent maps.
    pub err: Summary,
    /// Seconds per input vector through the batch transform on this
    /// cell's storage.
    pub secs_per_vec: f64,
    /// Wall-clock breakdown of the cell measurement itself, recorded
    /// in the run-log so a resumed render never re-measures (schema
    /// v4; pre-v4 run-logs decode these as zero).
    pub stages: StageSecs,
}

/// Per-stage wall-clock seconds spent measuring one cell: sampling the
/// `runs` independent maps, building the feature grams for the error
/// envelope, and the timed batch-transform iterations.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageSecs {
    pub sample_s: f64,
    pub gram_s: f64,
    pub transform_s: f64,
}

/// A cell's outcome: measured, or explicitly skipped with a reason.
#[derive(Clone, Debug)]
pub enum CellStatus {
    Ok(CellStats),
    Skipped { reason: String },
}

/// One rendered grid cell (spec echo + outcome).
#[derive(Clone, Debug)]
pub struct Cell {
    pub id: String,
    pub family: String,
    pub kernel: String,
    pub projection: String,
    pub storage: String,
    pub d: usize,
    pub status: CellStatus,
}

/// One Table-1-style accuracy entry (dataset × kernel × variant).
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub dataset: String,
    pub kernel: String,
    /// Column label (`K+SMO`, `RF+LIN`, `H0/1+LIN`, `RFF+LIN`, ...).
    pub variant: String,
    pub outcome: RowOutcome,
}

/// Outcome of one accuracy row.
#[derive(Clone, Debug)]
pub enum RowOutcome {
    Ok { accuracy: f64, train_s: f64, test_s: f64, size: usize },
    Skipped { reason: String },
}

/// One point of the thread-scaling sweep.
#[derive(Clone, Debug)]
pub struct ThreadPoint {
    pub threads: usize,
    pub secs: f64,
    /// Relative to the sweep's first entry.
    pub speedup: f64,
}

/// One point of the serving-throughput panel: the coordinator under a
/// synthetic client load, at one (worker count, queue topology)
/// configuration. `shards == 1` is the shared-queue baseline;
/// `shards == workers` the per-worker sharded topology with work
/// stealing.
#[derive(Clone, Debug)]
pub struct ServePoint {
    pub workers: usize,
    pub shards: usize,
    /// Completed requests per second (wall clock, like the transform
    /// cost columns: cached by the run-log, not seed-deterministic).
    pub reqs_per_s: f64,
    /// Request latency percentiles in microseconds (log-bucket upper
    /// edges from the coordinator's histogram).
    pub p50_us: f64,
    pub p90_us: f64,
    /// Batches executed by a worker whose home shard was elsewhere,
    /// summed over shards (0 by construction when `shards == 1`).
    pub steals: u64,
}

/// The fully assembled report — the in-memory mirror of `REPORT.json`.
#[derive(Clone, Debug)]
pub struct Report {
    pub version: u64,
    /// `"quick"` or `"full"`.
    pub mode: String,
    pub seed: u64,
    /// The kernel-dispatch path ([`crate::simd::selected`]) every
    /// measurement in this report ran on — timings recorded on
    /// different paths are not comparable (`rfdot bench-diff` makes the
    /// same distinction via the bench files' `simd` axis).
    pub simd: String,
    pub fingerprint: String,
    /// The grid axes this report was generated from.
    pub config: ReportConfig,
    /// Every declared cell, in [`grid`] order.
    pub cells: Vec<Cell>,
    pub accuracy: Vec<AccuracyRow>,
    pub threads: Vec<ThreadPoint>,
    /// The serving panel: coordinator throughput over worker count ×
    /// queue topology (shared vs sharded with work stealing).
    pub serving: Vec<ServePoint>,
}

/// FNV-1a over a cell id: an order-independent, dependency-free stream
/// label so every cell's RNG is a pure function of (master seed, id).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The gram-error point set: `points` vectors at ~25% density,
/// L2-normalized (the paper's protocol — unit sphere, so `R = 1` and
/// every kernel value is bounded), returned dense + CSR. Sparse cells
/// see the *same* values; storage changes cost, never results (the
/// crate's sparse parity contract).
fn point_set(config: &ReportConfig) -> (Matrix, SparseMatrix) {
    let mut rng = Rng::seed_from(config.seed ^ 0xDA7A);
    let mut x = Matrix::zeros(config.points, config.dim);
    for i in 0..config.points {
        loop {
            for j in 0..config.dim {
                let v = if rng.f64() < 0.25 { rng.f32() - 0.5 } else { 0.0 };
                x.set(i, j, v);
            }
            // Re-roll the (rare) all-zero row: the unit sphere has no
            // zero vector.
            if crate::linalg::normalize(x.row_mut(i)) > 0.0 {
                break;
            }
        }
    }
    let sx = SparseMatrix::from_dense(&x);
    (x, sx)
}

/// Exponential width σ² for a grid kernel (σ² = 0 means "fit from
/// data", which the synthetic unit-sphere set resolves to 1).
fn exp_sigma2(kspec: &KernelSpec) -> f64 {
    match kspec {
        KernelSpec::Exponential { sigma2 } if *sigma2 > 0.0 => *sigma2,
        _ => 1.0,
    }
}

/// The exact Gram matrix a family's estimator targets. Every family
/// targets `f(⟨x, y⟩)` except Random Fourier, whose own target is the
/// RBF kernel at `γ = 1/(2σ²)` — on the unit sphere that equals
/// `e^{−2γ} · exp(⟨x, y⟩/σ²)`, the exponential dot-product kernel up
/// to a constant factor.
fn exact_gram(family: Family, kspec: &KernelSpec, x: &Matrix) -> Matrix {
    match family {
        Family::Fourier => {
            let gamma = 0.5 / exp_sigma2(kspec);
            crate::linalg::symmetric_from_lower(x.rows(), 0, x.cols(), |i, j| {
                rbf(gamma, x.row(i), x.row(j)) as f32
            })
        }
        _ => crate::kernels::gram(kspec.build(1.0).as_ref(), x),
    }
}

/// Key of the exact-gram cache: Fourier targets differ from the shared
/// kernel-gram target.
fn exact_key(family: Family, kernel: &str) -> String {
    match family {
        Family::Fourier => format!("rbf|{kernel}"),
        _ => format!("kernel|{kernel}"),
    }
}

/// Sample/fit one map of the cell's family (the cell's RNG stream is
/// advanced once per map, so `runs` maps are independent).
fn sample_map(
    spec: &CellSpec,
    kspec: &KernelSpec,
    kernel: &dyn DotProductKernel,
    x: &Matrix,
    rng: &mut Rng,
) -> Result<Box<dyn FeatureMap>> {
    match spec.family {
        Family::Maclaurin => Ok(Box::new(RandomMaclaurin::sample(
            kernel,
            x.cols(),
            spec.d,
            RmConfig::default().with_projection(spec.projection),
            rng,
        ))),
        Family::MaclaurinH01 => Ok(Box::new(RandomMaclaurin::sample(
            kernel,
            x.cols(),
            spec.d,
            RmConfig::default().with_h01(true).with_projection(spec.projection),
            rng,
        ))),
        Family::Fourier => Ok(Box::new(RandomFourier::sample_with(
            0.5 / exp_sigma2(kspec),
            x.cols(),
            spec.d,
            spec.projection,
            rng,
        ))),
        Family::TensorSketch => {
            let (degree, offset) = match kspec {
                KernelSpec::Polynomial { degree, offset } => (*degree, *offset),
                KernelSpec::Homogeneous { degree } => (*degree, 0.0),
                other => {
                    return Err(Error::Config(format!(
                        "tensorsketch cannot sketch {other:?}"
                    )))
                }
            };
            Ok(Box::new(TensorSketch::sample(degree, offset, x.cols(), spec.d, rng)))
        }
        Family::Nystrom => Ok(Box::new(Nystrom::fit(kspec.build(1.0), x, spec.d, rng)?)),
    }
}

/// Measure one live cell: `runs` independent maps feed the gram-error
/// envelope (seed-deterministic), then one batch-transform timing on
/// the cell's storage sizes the cost column (wall-clock, cached by the
/// run-log rather than re-measured on resume).
fn run_cell(
    spec: &CellSpec,
    config: &ReportConfig,
    x: &Matrix,
    sx: &SparseMatrix,
    exact: &Matrix,
) -> Result<CellStats> {
    let kspec = KernelSpec::parse(&spec.kernel)?;
    let kernel = kspec.build(1.0);
    let mut rng = Rng::seed_from(config.seed ^ fnv1a(&spec.seed_key()));
    let mut errs = Vec::with_capacity(config.runs);
    let mut last: Option<Box<dyn FeatureMap>> = None;
    let mut stages = StageSecs::default();
    for _ in 0..config.runs {
        let sw = crate::metrics::Stopwatch::start();
        let map = sample_map(spec, &kspec, kernel.as_ref(), x, &mut rng)?;
        stages.sample_s += sw.elapsed_secs();
        let sw = crate::metrics::Stopwatch::start();
        let approx = match spec.storage {
            StorageKind::Dense => crate::features::feature_gram(map.as_ref(), x),
            StorageKind::Sparse => crate::features::feature_gram_sparse(map.as_ref(), sx),
        };
        errs.push(crate::kernels::mean_abs_gram_error(exact, &approx));
        stages.gram_s += sw.elapsed_secs();
        last = Some(map);
    }
    let map = last.expect("runs >= 1 by validation");
    let iters = if config.quick { 2 } else { 5 };
    let sw = crate::metrics::Stopwatch::start();
    let m = crate::bench::bench("cell-transform", 1, iters, || match spec.storage {
        StorageKind::Dense => map.transform_batch(x),
        StorageKind::Sparse => map.transform_batch_sparse(sx),
    });
    stages.transform_s = sw.elapsed_secs();
    // Mirror the breakdown into the live metrics registry so a
    // `MetricsSnapshot` taken mid-grid sees where the time went; the
    // report itself only ever reads the run-log copy.
    crate::obs::histogram("report.cell.sample_us").record_f64(stages.sample_s * 1e6);
    crate::obs::histogram("report.cell.gram_us").record_f64(stages.gram_s * 1e6);
    crate::obs::histogram("report.cell.transform_us").record_f64(stages.transform_s * 1e6);
    Ok(CellStats {
        output_dim: map.output_dim(),
        err: Summary::from_samples(&errs),
        secs_per_vec: m.mean_s() / x.rows() as f64,
        stages,
    })
}

/// The Table-1-style accuracy section: for each dataset × kernel, the
/// exact kernel SVM plus every feature-map family at the configured D,
/// through [`experiment::run_variant`]. Inapplicable variants become
/// explicit skips, mirroring the grid's no-silent-drops rule.
fn accuracy_rows(config: &ReportConfig) -> Result<Vec<AccuracyRow>> {
    let mut rows = Vec::new();
    for dataset in &config.datasets {
        for kernel in &config.kernels {
            let exp_cfg = ExperimentConfig {
                dataset: dataset.clone(),
                kernel: KernelSpec::parse(kernel)?,
                scale: config.scale,
                n_features: config.accuracy_features,
                seed: config.seed,
                ..Default::default()
            };
            let prep = experiment::prepare(&exp_cfg)?;
            let d = config.accuracy_features;
            let variants = [
                MapVariant::Exact,
                MapVariant::Maclaurin { d, h01: false },
                MapVariant::Maclaurin { d, h01: true },
                MapVariant::Fourier { d },
                MapVariant::TensorSketch { d },
                MapVariant::Nystrom { m: d },
            ];
            for (i, variant) in variants.iter().enumerate() {
                let outcome = match experiment::run_variant(&prep, variant, 1 + i as u64) {
                    Ok(cell) => RowOutcome::Ok {
                        accuracy: cell.accuracy,
                        train_s: cell.train_s,
                        test_s: cell.test_s,
                        size: cell.size,
                    },
                    Err(e) => RowOutcome::Skipped { reason: e.to_string() },
                };
                rows.push(AccuracyRow {
                    dataset: dataset.clone(),
                    kernel: kernel.clone(),
                    variant: variant.label(),
                    outcome,
                });
            }
        }
    }
    Ok(rows)
}

/// The `transform_batch` thread-scaling sweep on a Random Maclaurin map
/// (the crate's headline hot path), with explicit per-call thread
/// counts — the process-global [`crate::parallel`] knob is never
/// touched.
fn thread_sweep(config: &ReportConfig, x: &Matrix) -> Result<Vec<ThreadPoint>> {
    let kspec = KernelSpec::parse(&config.kernels[0])?;
    let kernel = kspec.build(1.0);
    let d = *config.d_sweep.last().expect("validated non-empty");
    let mut rng = Rng::seed_from(config.seed ^ 0x7423);
    let map = RandomMaclaurin::sample(kernel.as_ref(), x.cols(), d, RmConfig::default(), &mut rng);
    let iters = if config.quick { 2 } else { 5 };
    let mut points = Vec::new();
    let mut base = 0.0;
    for &t in &config.threads_sweep {
        let secs =
            crate::bench::bench("thread-sweep", 1, iters, || map.transform_batch_threads(x, t))
                .mean_s();
        if points.is_empty() {
            base = secs;
        }
        points.push(ThreadPoint { threads: t, secs, speedup: base / secs.max(1e-12) });
    }
    Ok(points)
}

/// The serving panel measurement: a native-backed coordinator under a
/// synthetic concurrent client load, swept over worker count (the
/// config's `threads_sweep` axis) × queue topology (`shards = 1`, the
/// pre-shard shared queue, vs `shards = workers`, per-worker shards
/// with work stealing). Replies are bit-identical across topologies
/// (the serving parity contract, `rust/tests/serve_shard.rs`); this
/// panel records what changes — throughput, latency percentiles and
/// steal counts.
fn serve_sweep(config: &ReportConfig) -> Result<Vec<ServePoint>> {
    use crate::coordinator::{Coordinator, CoordinatorConfig, MapArtifactFactory};
    use std::sync::Arc;

    let kspec = KernelSpec::parse(&config.kernels[0])?;
    let kernel = kspec.build(1.0);
    let d = config.dim;
    let dd = *config.d_sweep.last().expect("validated non-empty");
    let mut rng = Rng::seed_from(config.seed ^ 0x5E87E);
    let map = RandomMaclaurin::sample(kernel.as_ref(), d, dd, RmConfig::default(), &mut rng);
    // One zero-copy artifact serves every topology in the sweep: each
    // coordinator's workers borrow the same read-only weight region
    // (replies are bit-identical to an owned map — the artifact parity
    // contract, `rust/tests/artifact_shared.rs`).
    let artifact = Arc::new(crate::artifact::MapArtifact::from_map(&map)?);
    let mut points = Vec::new();
    for &workers in &config.threads_sweep {
        // workers == 1 has only one topology; dedup it.
        let mut topologies = vec![1usize];
        if workers > 1 {
            topologies.push(workers);
        }
        for &shards in &topologies {
            let coord = Arc::new(Coordinator::start(
                Arc::new(MapArtifactFactory::new(artifact.clone())?),
                CoordinatorConfig {
                    workers,
                    shards,
                    max_batch: 64,
                    max_wait: std::time::Duration::from_micros(200),
                    queue_depth: 8192,
                    intra_op_threads: 1,
                },
            ));
            let clients = 4usize;
            let per_client = (config.serve_requests / clients).max(1);
            let sw = crate::metrics::Stopwatch::start();
            let mut handles = Vec::new();
            for c in 0..clients {
                let coord = coord.clone();
                let seed = config.seed ^ (0xC11E47 + c as u64);
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::seed_from(seed);
                    let mut ok = 0usize;
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
                        if let Ok(t) = coord.submit(x) {
                            if t.wait().is_ok() {
                                ok += 1;
                            }
                        }
                    }
                    ok
                }));
            }
            let completed: usize = handles
                .into_iter()
                .map(|h| h.join().expect("serve-sweep client"))
                .sum();
            let dt = sw.elapsed_secs().max(1e-9);
            let stats = coord.stats();
            let steals: u64 = coord.shard_snapshots().iter().map(|s| s.steals).sum();
            points.push(ServePoint {
                workers,
                shards,
                reqs_per_s: completed as f64 / dt,
                p50_us: stats.latency_quantile_us(0.5) as f64,
                p90_us: stats.latency_quantile_us(0.9) as f64,
                steals,
            });
        }
    }
    Ok(points)
}

/// The resumable run-log: everything completed so far, keyed by the
/// config [`ReportConfig::fingerprint`]. Saved after every finished
/// cell, so interrupting a full-grid run loses at most one cell, and
/// re-rendering from a complete log reproduces the report byte for
/// byte (wall-clock timings are cached alongside the deterministic
/// statistics).
pub struct RunLog {
    pub fingerprint: String,
    pub cells: BTreeMap<String, Cell>,
    pub accuracy: Option<Vec<AccuracyRow>>,
    pub threads: Option<Vec<ThreadPoint>>,
    pub serving: Option<Vec<ServePoint>>,
    path: PathBuf,
}

impl RunLog {
    /// Load the log at `path` if it exists, resuming is enabled and its
    /// fingerprint matches; otherwise start empty.
    pub fn load_or_new(path: PathBuf, fingerprint: &str, resume: bool) -> RunLog {
        let empty = RunLog {
            fingerprint: fingerprint.to_string(),
            cells: BTreeMap::new(),
            accuracy: None,
            threads: None,
            serving: None,
            path,
        };
        if !resume {
            return empty;
        }
        let Ok(text) = std::fs::read_to_string(&empty.path) else {
            return empty;
        };
        match render::parse_runlog(&text, empty.path.clone()) {
            Ok(log) if log.fingerprint == fingerprint => log,
            _ => empty,
        }
    }

    fn save(&self) -> Result<()> {
        std::fs::write(&self.path, render::runlog_json(self).pretty())?;
        Ok(())
    }
}

/// Run the whole declared grid and regenerate `REPORT.md`,
/// `REPORT.json` and the `report/*.svg` assets under
/// `config.out_dir`, resuming from the run-log when possible. The
/// written `REPORT.json` is re-parsed through [`parse_report`] before
/// returning — the self-check CI's `report --quick` smoke relies on to
/// fail on schema drift.
pub fn run(config: &ReportConfig) -> Result<Report> {
    config.validate()?;
    let out_dir = Path::new(&config.out_dir);
    std::fs::create_dir_all(out_dir.join("report"))?;
    let fingerprint = config.fingerprint();
    let mut log = RunLog::load_or_new(
        out_dir.join("report_runlog.json"),
        &fingerprint,
        config.resume,
    );
    let specs = grid(config);
    let (x, sx) = point_set(config);
    let mut exact_cache: BTreeMap<String, Matrix> = BTreeMap::new();
    for spec in &specs {
        let id = spec.id();
        if log.cells.contains_key(&id) {
            continue;
        }
        let kspec = KernelSpec::parse(&spec.kernel)?;
        let status = match skip_reason(spec, &kspec) {
            Some(reason) => CellStatus::Skipped { reason },
            None => {
                let key = exact_key(spec.family, &spec.kernel);
                let exact = exact_cache
                    .entry(key)
                    .or_insert_with(|| exact_gram(spec.family, &kspec, &x));
                CellStatus::Ok(run_cell(spec, config, &x, &sx, exact)?)
            }
        };
        let cell = Cell {
            id: id.clone(),
            family: spec.family.id().to_string(),
            kernel: spec.kernel.clone(),
            projection: spec.projection.as_str().to_string(),
            storage: spec.storage.as_str().to_string(),
            d: spec.d,
            status,
        };
        log.cells.insert(id, cell);
        log.save()?;
    }
    if log.accuracy.is_none() {
        log.accuracy = Some(accuracy_rows(config)?);
        log.save()?;
    }
    if log.threads.is_none() {
        log.threads = Some(thread_sweep(config, &x)?);
        log.save()?;
    }
    if log.serving.is_none() {
        log.serving = Some(serve_sweep(config)?);
        log.save()?;
    }

    let report = Report {
        version: REPORT_VERSION,
        mode: if config.quick { "quick".into() } else { "full".into() },
        seed: config.seed,
        simd: crate::simd::selected().as_str().to_string(),
        fingerprint,
        config: config.clone(),
        cells: specs
            .iter()
            .map(|s| log.cells.get(&s.id()).expect("every spec was filled in").clone())
            .collect(),
        accuracy: log.accuracy.clone().expect("filled above"),
        threads: log.threads.clone().expect("filled above"),
        serving: log.serving.clone().expect("filled above"),
    };
    render::write_all(&report, out_dir)?;
    let written = std::fs::read_to_string(out_dir.join("REPORT.json"))?;
    parse_report(&written)?;
    Ok(report)
}

/// Deserialize a `REPORT.json` document back into the typed schema,
/// validating version, statuses and per-status required fields. This is
/// the drift gate: anything [`render::report_json`] starts emitting
/// that this function does not understand fails the round-trip in
/// [`run`], the schema test and the CI smoke.
pub fn parse_report(text: &str) -> Result<Report> {
    let doc = Json::parse(text)?;
    render::decode_report(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_the_declared_cross_product() {
        let config = ReportConfig::quick();
        let specs = grid(&config);
        let expected = FAMILIES.len() * config.kernels.len() * 2 * 2 * config.d_sweep.len();
        assert_eq!(specs.len(), expected);
        // Ids are unique (the run-log key space).
        let ids: std::collections::BTreeSet<String> = specs.iter().map(|s| s.id()).collect();
        assert_eq!(ids.len(), specs.len());
    }

    #[test]
    fn skip_reasons_encode_applicability() {
        let poly = KernelSpec::parse("poly:3:1").unwrap();
        let hom = KernelSpec::parse("hom:4").unwrap();
        let exp = KernelSpec::parse("exp:1").unwrap();
        let spec = |family, projection| CellSpec {
            family,
            kernel: "k".into(),
            projection,
            storage: StorageKind::Dense,
            d: 16,
        };
        let d = ProjectionKind::Dense;
        let s = ProjectionKind::Structured;
        assert!(skip_reason(&spec(Family::Maclaurin, s), &hom).is_none());
        assert!(skip_reason(&spec(Family::MaclaurinH01, d), &poly).is_none());
        assert!(skip_reason(&spec(Family::MaclaurinH01, d), &hom).is_some());
        assert!(skip_reason(&spec(Family::Fourier, s), &exp).is_none());
        assert!(skip_reason(&spec(Family::Fourier, d), &poly).is_some());
        assert!(skip_reason(&spec(Family::TensorSketch, d), &poly).is_none());
        assert!(skip_reason(&spec(Family::TensorSketch, s), &poly).is_some());
        assert!(skip_reason(&spec(Family::TensorSketch, d), &exp).is_some());
        assert!(skip_reason(&spec(Family::Nystrom, d), &exp).is_none());
        assert!(skip_reason(&spec(Family::Nystrom, s), &exp).is_some());
    }

    #[test]
    fn family_ids_round_trip() {
        for f in FAMILIES {
            assert_eq!(Family::parse(f.id()).unwrap(), f);
        }
        assert!(Family::parse("nope").is_err());
        assert_eq!(StorageKind::parse("sparse").unwrap(), StorageKind::Sparse);
        assert!(StorageKind::parse("csr").is_err());
    }

    #[test]
    fn cell_seeds_are_order_independent_and_storage_blind() {
        // The per-cell stream depends only on (seed, seed_key) — the
        // property resume determinism rests on.
        assert_eq!(fnv1a("a|b"), fnv1a("a|b"));
        assert_ne!(fnv1a("rm|poly:3:1|dense|D16"), fnv1a("rm|poly:3:1|dense|D32"));
        // Twin cells across the storage axis share a stream (the report
        // surfaces the sparse parity contract through equal envelopes),
        // while their run-log ids stay distinct.
        let mut dense = CellSpec {
            family: Family::Maclaurin,
            kernel: "poly:3:1".into(),
            projection: ProjectionKind::Dense,
            storage: StorageKind::Dense,
            d: 16,
        };
        let sparse = CellSpec { storage: StorageKind::Sparse, ..dense.clone() };
        assert_eq!(dense.seed_key(), sparse.seed_key());
        assert_ne!(dense.id(), sparse.id());
        dense.d = 32;
        assert_ne!(dense.seed_key(), sparse.seed_key());
    }

    #[test]
    fn point_set_is_unit_norm_sparse_and_seeded() {
        let config = ReportConfig::quick();
        let (x, sx) = point_set(&config);
        assert_eq!(x.rows(), config.points);
        for i in 0..x.rows() {
            let n = crate::linalg::norm2(x.row(i));
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
        assert!(sx.density() < 0.7, "density {}", sx.density());
        assert_eq!(sx.to_dense(), x);
        let (x2, _) = point_set(&config);
        assert_eq!(x, x2, "point set must be a pure function of the seed");
    }

    #[test]
    fn exact_gram_fourier_targets_scaled_exponential() {
        // On the unit sphere: rbf(γ=1/2σ², x, y) = e^{−2γ}·exp(t/σ²).
        let config = ReportConfig::quick();
        let (x, _) = point_set(&config);
        let exp = KernelSpec::parse("exp:1").unwrap();
        let g_rbf = exact_gram(Family::Fourier, &exp, &x);
        let g_exp = exact_gram(Family::Maclaurin, &exp, &x);
        let c = (-1.0f64).exp();
        for i in 0..x.rows() {
            for j in 0..x.rows() {
                let want = c * g_exp.get(i, j) as f64;
                let got = g_rbf.get(i, j) as f64;
                assert!((got - want).abs() < 1e-4, "({i},{j}): {got} vs {want}");
            }
        }
    }
}
