//! Minimal deterministic SVG plotting (no external crates).
//!
//! The report's two chart shapes — error-vs-D line charts and speedup
//! bar charts — rendered as hand-written SVG text, in the same spirit
//! as `benches/micro.rs` writing its JSON baselines by hand. Output is
//! a pure function of the input data with fixed-precision coordinate
//! formatting, so regenerating a report from cached results reproduces
//! every asset byte for byte (the regeneration contract of
//! [`crate::report`]).

/// One polyline of a [`line_chart`].
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    /// `(x, y)` points, plotted in the given order.
    pub points: Vec<(f64, f64)>,
}

const WIDTH: f64 = 720.0;
const HEIGHT: f64 = 400.0;
/// Plot-area margins: left, right (legend gutter), top, bottom.
const MARGIN: (f64, f64, f64, f64) = (70.0, 190.0, 40.0, 50.0);
/// Color cycle (shared by lines and bars).
const COLORS: [&str; 6] = ["#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e", "#8c564b"];

/// Escape text nodes / attribute values.
fn xml_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            c => out.push(c),
        }
    }
    out
}

/// Tick/legend number formatting: fixed precision per magnitude band so
/// output is deterministic and compact (shared with the markdown
/// renderer in [`super::render`]).
pub(crate) fn fmt_num(v: f64) -> String {
    let a = v.abs();
    if v == 0.0 {
        "0".into()
    } else if a >= 1000.0 || a < 0.001 {
        format!("{v:.1e}")
    } else if a >= 100.0 {
        format!("{v:.0}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

fn header(title: &str) -> String {
    format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" viewBox=\"0 0 {WIDTH:.0} {HEIGHT:.0}\" \
         font-family=\"sans-serif\" font-size=\"12\">\n\
         <rect width=\"{WIDTH:.0}\" height=\"{HEIGHT:.0}\" fill=\"white\"/>\n\
         <text x=\"{:.0}\" y=\"22\" font-size=\"15\" text-anchor=\"middle\">{}</text>\n",
        WIDTH / 2.0,
        xml_escape(title),
    )
}

/// Linear map from a data range onto a pixel range (degenerate ranges
/// land mid-span so single-point series stay visible).
fn scale(v: f64, lo: f64, hi: f64, px_lo: f64, px_hi: f64) -> f64 {
    if hi > lo {
        px_lo + (v - lo) / (hi - lo) * (px_hi - px_lo)
    } else {
        (px_lo + px_hi) / 2.0
    }
}

/// A log-log line chart (the Figure-1 shape: error vs D on doubling
/// axes). Points with non-positive coordinates are dropped (they have
/// no logarithm); an empty chart renders a "no data" placeholder so
/// per-family assets always exist.
pub fn line_chart(title: &str, x_label: &str, y_label: &str, series: &[Series]) -> String {
    let (ml, mr, mt, mb) = MARGIN;
    let (px0, px1) = (ml, WIDTH - mr);
    let (py0, py1) = (HEIGHT - mb, mt);
    let mut svg = header(title);

    let logs: Vec<(usize, Vec<(f64, f64)>)> = series
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let pts = s
                .points
                .iter()
                .filter(|(x, y)| *x > 0.0 && *y > 0.0)
                .map(|(x, y)| (x.log10(), y.log10()))
                .collect();
            (i, pts)
        })
        .collect();
    let all: Vec<(f64, f64)> = logs.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        svg.push_str(&format!(
            "<text x=\"{:.0}\" y=\"{:.0}\" text-anchor=\"middle\" fill=\"#888\">\
             no applicable cells</text>\n</svg>\n",
            WIDTH / 2.0,
            HEIGHT / 2.0,
        ));
        return svg;
    }
    let (mut xlo, mut xhi) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ylo, mut yhi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &all {
        xlo = xlo.min(*x);
        xhi = xhi.max(*x);
        ylo = ylo.min(*y);
        yhi = yhi.max(*y);
    }

    // Axes + 4 ticks per axis (even fractions of the log range, labeled
    // in linear units).
    svg.push_str(&format!(
        "<line x1=\"{px0:.1}\" y1=\"{py0:.1}\" x2=\"{px1:.1}\" y2=\"{py0:.1}\" stroke=\"#333\"/>\n\
         <line x1=\"{px0:.1}\" y1=\"{py0:.1}\" x2=\"{px0:.1}\" y2=\"{py1:.1}\" stroke=\"#333\"/>\n",
    ));
    for k in 0..4 {
        let f = k as f64 / 3.0;
        let lx = xlo + f * (xhi - xlo);
        let ly = ylo + f * (yhi - ylo);
        let px = scale(lx, xlo, xhi, px0, px1);
        let py = scale(ly, ylo, yhi, py0, py1);
        svg.push_str(&format!(
            "<line x1=\"{px:.1}\" y1=\"{py0:.1}\" x2=\"{px:.1}\" y2=\"{:.1}\" stroke=\"#333\"/>\n\
             <text x=\"{px:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
            py0 + 5.0,
            py0 + 18.0,
            xml_escape(&fmt_num(10f64.powf(lx))),
        ));
        svg.push_str(&format!(
            "<line x1=\"{:.1}\" y1=\"{py:.1}\" x2=\"{px0:.1}\" y2=\"{py:.1}\" stroke=\"#333\"/>\n\
             <text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\">{}</text>\n",
            px0 - 5.0,
            px0 - 8.0,
            py + 4.0,
            xml_escape(&fmt_num(10f64.powf(ly))),
        ));
    }
    svg.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
        (px0 + px1) / 2.0,
        HEIGHT - 12.0,
        xml_escape(x_label),
    ));
    svg.push_str(&format!(
        "<text x=\"16\" y=\"{:.1}\" text-anchor=\"middle\" \
         transform=\"rotate(-90 16 {:.1})\">{}</text>\n",
        (py0 + py1) / 2.0,
        (py0 + py1) / 2.0,
        xml_escape(y_label),
    ));

    // Series polylines + markers + legend.
    let mut legend_row = 0usize;
    for (i, pts) in &logs {
        if pts.is_empty() {
            continue;
        }
        let color = COLORS[i % COLORS.len()];
        let coords: Vec<String> = pts
            .iter()
            .map(|(x, y)| {
                format!(
                    "{:.1},{:.1}",
                    scale(*x, xlo, xhi, px0, px1),
                    scale(*y, ylo, yhi, py0, py1)
                )
            })
            .collect();
        svg.push_str(&format!(
            "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"2\"/>\n",
            coords.join(" "),
        ));
        for c in &coords {
            let (cx, cy) = c.split_once(',').expect("formatted above");
            svg.push_str(&format!("<circle cx=\"{cx}\" cy=\"{cy}\" r=\"3\" fill=\"{color}\"/>\n"));
        }
        let ly = py1 + 10.0 + legend_row as f64 * 18.0;
        svg.push_str(&format!(
            "<line x1=\"{:.1}\" y1=\"{ly:.1}\" x2=\"{:.1}\" y2=\"{ly:.1}\" \
             stroke=\"{color}\" stroke-width=\"2\"/>\n\
             <text x=\"{:.1}\" y=\"{:.1}\">{}</text>\n",
            px1 + 10.0,
            px1 + 34.0,
            px1 + 40.0,
            ly + 4.0,
            xml_escape(&series[*i].label),
        ));
        legend_row += 1;
    }
    svg.push_str("</svg>\n");
    svg
}

/// A labeled vertical bar chart (the speedup shape). Bar values are
/// printed above each bar; the dashed line marks 1× (parity). An empty
/// input renders the same "no data" placeholder as [`line_chart`].
pub fn bar_chart(title: &str, y_label: &str, bars: &[(String, f64)]) -> String {
    let (ml, _, mt, mb) = MARGIN;
    let (px0, px1) = (ml, WIDTH - 30.0);
    let (py0, py1) = (HEIGHT - mb, mt);
    let mut svg = header(title);
    if bars.is_empty() {
        svg.push_str(&format!(
            "<text x=\"{:.0}\" y=\"{:.0}\" text-anchor=\"middle\" fill=\"#888\">\
             no applicable cells</text>\n</svg>\n",
            WIDTH / 2.0,
            HEIGHT / 2.0,
        ));
        return svg;
    }
    let vmax = bars.iter().fold(1.0f64, |m, (_, v)| m.max(*v));
    svg.push_str(&format!(
        "<line x1=\"{px0:.1}\" y1=\"{py0:.1}\" x2=\"{px1:.1}\" y2=\"{py0:.1}\" stroke=\"#333\"/>\n\
         <line x1=\"{px0:.1}\" y1=\"{py0:.1}\" x2=\"{px0:.1}\" y2=\"{py1:.1}\" stroke=\"#333\"/>\n",
    ));
    // Parity line at 1x.
    let parity = scale(1.0, 0.0, vmax, py0, py1);
    svg.push_str(&format!(
        "<line x1=\"{px0:.1}\" y1=\"{parity:.1}\" x2=\"{px1:.1}\" y2=\"{parity:.1}\" \
         stroke=\"#999\" stroke-dasharray=\"4 3\"/>\n\
         <text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\" fill=\"#999\">1x</text>\n",
        px0 - 5.0,
        parity + 4.0,
    ));
    svg.push_str(&format!(
        "<text x=\"16\" y=\"{:.1}\" text-anchor=\"middle\" \
         transform=\"rotate(-90 16 {:.1})\">{}</text>\n",
        (py0 + py1) / 2.0,
        (py0 + py1) / 2.0,
        xml_escape(y_label),
    ));
    let slot = (px1 - px0) / bars.len() as f64;
    let bar_w = (slot * 0.6).min(60.0);
    for (i, (label, v)) in bars.iter().enumerate() {
        let color = COLORS[i % COLORS.len()];
        let cx = px0 + (i as f64 + 0.5) * slot;
        let top = scale(v.max(0.0), 0.0, vmax, py0, py1);
        svg.push_str(&format!(
            "<rect x=\"{:.1}\" y=\"{top:.1}\" width=\"{bar_w:.1}\" height=\"{:.1}\" \
             fill=\"{color}\"/>\n\
             <text x=\"{cx:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n\
             <text x=\"{cx:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}x</text>\n",
            cx - bar_w / 2.0,
            py0 - top,
            py0 + 18.0,
            xml_escape(label),
            top - 6.0,
            xml_escape(&fmt_num(*v)),
        ));
    }
    svg.push_str("</svg>\n");
    svg
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> Vec<Series> {
        vec![
            Series {
                label: "poly (dense)".into(),
                points: vec![(16.0, 0.5), (32.0, 0.35), (64.0, 0.25)],
            },
            Series { label: "exp <&> structured".into(), points: vec![(16.0, 0.4), (64.0, 0.2)] },
        ]
    }

    #[test]
    fn line_chart_is_wellformed_and_deterministic() {
        let a = line_chart("error vs D", "D", "mean |err|", &series());
        let b = line_chart("error vs D", "D", "mean |err|", &series());
        assert_eq!(a, b, "same data must render identical bytes");
        assert!(a.starts_with("<svg"));
        assert!(a.ends_with("</svg>\n"));
        assert_eq!(a.matches("<polyline").count(), 2);
        assert!(a.contains("&lt;&amp;&gt;"), "labels must be XML-escaped");
        // Tag balance (crude well-formedness check).
        assert_eq!(a.matches("<svg").count(), a.matches("</svg>").count());
        assert_eq!(a.matches("<text").count(), a.matches("</text>").count());
    }

    #[test]
    fn line_chart_drops_nonpositive_points_and_survives_empty() {
        let s = vec![Series { label: "bad".into(), points: vec![(0.0, 1.0), (4.0, -1.0)] }];
        let svg = line_chart("t", "x", "y", &s);
        assert!(svg.contains("no applicable cells"));
        let empty = line_chart("t", "x", "y", &[]);
        assert!(empty.contains("no applicable cells"));
    }

    #[test]
    fn bar_chart_renders_bars_and_parity_line() {
        let bars = vec![("sparse D64".to_string(), 5.2), ("structured D64".to_string(), 0.8)];
        let svg = bar_chart("speedup", "x faster", &bars);
        assert_eq!(svg.matches("<rect").count(), 3, "background + 2 bars");
        assert!(svg.contains("stroke-dasharray"));
        assert!(svg.contains("5.20x"));
        assert!(bar_chart("t", "y", &[]).contains("no applicable cells"));
    }
}
