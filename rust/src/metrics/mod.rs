//! Lightweight runtime metrics (atomic counters + latency histogram).
//!
//! The coordinator's hot path records into these with relaxed atomics —
//! no locks, no allocation. `snapshot()` gives a consistent-enough view
//! for logs, the `serve` example and the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-scale latency histogram: bucket `i` counts samples in
/// `[2^i, 2^(i+1)) µs`, 0..=24 (1 µs .. ~16 s).
const BUCKETS: usize = 25;

/// Metrics for one coordinator instance.
#[derive(Debug, Default)]
pub struct Stats {
    /// Requests accepted into the queue.
    pub submitted: AtomicU64,
    /// Requests answered (ok or error).
    pub completed: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Batches dispatched to the backend.
    pub batches: AtomicU64,
    /// Subset of `batches` that were pre-formed full batches pushed
    /// straight onto a shard, bypassing the batcher thread.
    pub direct_batches: AtomicU64,
    /// Sum of real (unpadded) batch sizes.
    pub batched_items: AtomicU64,
    /// Pad slots wasted on fixed-shape backends.
    pub pad_slots: AtomicU64,
    /// Backend failures.
    pub backend_errors: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    /// Samples recorded into `latency_sum_us` — the mean's denominator.
    /// Deliberately distinct from `completed`: latencies may be recorded
    /// on a different path (or not at all) than completion counting, and
    /// dividing the sum by `completed` silently skews the mean.
    latency_samples: AtomicU64,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request latency.
    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        self.latency_samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency quantile in microseconds (upper bucket edge).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.latency.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Mean latency in microseconds over the *recorded samples* (not
    /// the `completed` counter, which may advance on paths that never
    /// record a latency).
    pub fn mean_latency_us(&self) -> f64 {
        let samples = self.latency_samples.load(Ordering::Relaxed);
        if samples == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / samples as f64
    }

    /// Mean real batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} batches={} mean_batch={:.1} pad={} errs={} lat_mean={:.0}us p50<={}us p99<={}us",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.pad_slots.load(Ordering::Relaxed),
            self.backend_errors.load(Ordering::Relaxed),
            self.mean_latency_us(),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
        )
    }
}

/// Percentile summary of a batch of `f64` samples — the shared per-cell
/// statistic of the report grid (`rfdot report` renders one of these
/// for every error envelope) and of any bench that wants more than
/// mean ± stddev. Percentiles use the nearest-rank rule, so every
/// reported value is an actual sample (no interpolation, deterministic
/// for a deterministic sample set).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Number of samples summarized.
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize `xs` (NaN-free by contract; an empty slice yields the
    /// all-zero summary).
    pub fn from_samples(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, min: 0.0, p50: 0.0, p90: 0.0, max: 0.0 };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN-free samples"));
        let pick = |q: f64| -> f64 {
            let rank = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
            sorted[rank.min(sorted.len() - 1)]
        };
        Summary {
            n: sorted.len(),
            mean: crate::linalg::mean(&sorted),
            min: sorted[0],
            p50: pick(0.5),
            p90: pick(0.9),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// A bounded, shareable raw-sample recorder for benches and one-shot
/// measurements that want true nearest-rank percentiles over the
/// actual samples.
///
/// Unlike the log-bucketed histogram in [`Stats`] (whose quantiles are
/// power-of-two upper edges), this keeps the raw samples, so to bound
/// memory recording stops after `cap` samples — a warm-up window, not
/// a steady-state view. Overflow is *visible*: every sample dropped
/// past the cap is counted and exposed via [`SampleBuffer::dropped`],
/// so a saturated window can never masquerade as a complete one. The
/// serving layer's per-shard latency no longer lives here — it records
/// into [`crate::obs::Histogram`], which has bounded memory *and*
/// never stops recording.
#[derive(Debug)]
pub struct SampleBuffer {
    cap: usize,
    samples: std::sync::Mutex<Vec<f64>>,
    dropped: AtomicU64,
}

impl SampleBuffer {
    /// An empty buffer that keeps at most `cap` samples.
    pub fn new(cap: usize) -> SampleBuffer {
        SampleBuffer {
            cap,
            samples: std::sync::Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<f64>> {
        // Tolerate poisoning: a panicked recorder leaves a perfectly
        // usable Vec behind, and metrics must never compound a failure.
        self.samples.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Record one sample (counted as dropped once the buffer is full).
    pub fn record(&self, v: f64) {
        self.record_many(std::slice::from_ref(&v));
    }

    /// Record a batch of samples under one lock acquisition, so
    /// per-item recorders never contend on this mutex. Samples beyond
    /// the cap are dropped — and counted, see [`SampleBuffer::dropped`].
    pub fn record_many(&self, vs: &[f64]) {
        if vs.is_empty() {
            return;
        }
        let mut s = self.lock();
        let room = self.cap.saturating_sub(s.len());
        let kept = vs.len().min(room);
        s.extend_from_slice(&vs[..kept]);
        if kept < vs.len() {
            self.dropped.fetch_add((vs.len() - kept) as u64, Ordering::Relaxed);
        }
    }

    /// Samples recorded so far (≤ the construction cap).
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// Samples discarded because the buffer was already at capacity.
    /// Nonzero means [`SampleBuffer::summary`] describes only the
    /// warm-up window, not the full run.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Nearest-rank percentile summary of the recorded samples.
    pub fn summary(&self) -> Summary {
        Summary::from_samples(&self.lock())
    }
}

/// A simple wall-clock stopwatch (used by benches and the CLI).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = Stats::new();
        s.submitted.fetch_add(3, Ordering::Relaxed);
        s.completed.fetch_add(2, Ordering::Relaxed);
        assert!(s.summary().contains("submitted=3"));
    }

    #[test]
    fn latency_quantiles_monotone() {
        let s = Stats::new();
        for us in [10u64, 100, 1000, 10_000] {
            s.record_latency(Duration::from_micros(us));
        }
        s.completed.store(4, Ordering::Relaxed);
        let p50 = s.latency_quantile_us(0.5);
        let p99 = s.latency_quantile_us(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 64 && p50 <= 256, "p50 {p50}");
        assert!(s.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = Stats::new();
        assert_eq!(s.latency_quantile_us(0.99), 0);
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
    }

    #[test]
    fn mean_latency_divides_by_samples_not_completed() {
        // Regression: the mean used to divide latency_sum by the
        // `completed` counter, skewing it whenever completions are
        // counted on a path that records no latency. Pin the two apart.
        let s = Stats::new();
        s.record_latency(Duration::from_micros(100));
        s.record_latency(Duration::from_micros(300));
        // Five completions, only two recorded latencies (e.g. a backend
        // that answers some requests without timing them).
        s.completed.store(5, Ordering::Relaxed);
        assert!((s.mean_latency_us() - 200.0).abs() < 1e-9, "got {}", s.mean_latency_us());
        // And with zero completions but recorded samples, the mean must
        // still be the sample mean (the old code returned 0).
        let t = Stats::new();
        t.record_latency(Duration::from_micros(50));
        assert!((t.mean_latency_us() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn summary_percentiles_nearest_rank() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        let s = Summary::from_samples(&xs);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p90, 5.0);
        assert_eq!(s.max, 5.0);
        // Nearest rank: every percentile is an actual sample.
        assert!(xs.contains(&s.p50) && xs.contains(&s.p90));
    }

    #[test]
    fn summary_degenerate_inputs() {
        let empty = Summary::from_samples(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.mean, 0.0);
        let one = Summary::from_samples(&[7.5]);
        assert_eq!((one.min, one.p50, one.p90, one.max), (7.5, 7.5, 7.5, 7.5));
    }

    #[test]
    fn sample_buffer_caps_and_summarizes() {
        let b = SampleBuffer::new(3);
        assert!(b.is_empty());
        assert_eq!(b.summary().n, 0);
        assert_eq!(b.dropped(), 0);
        b.record(30.0);
        b.record_many(&[10.0, 20.0, 99.0]);
        // The fourth sample fell off the cap — visibly.
        assert_eq!(b.len(), 3);
        assert_eq!(b.dropped(), 1);
        let s = b.summary();
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 10.0);
        assert_eq!(s.max, 30.0);
        assert_eq!(s.p50, 20.0);
        // Further records past the cap keep counting.
        b.record(1.0);
        b.record_many(&[2.0, 3.0]);
        assert_eq!(b.dropped(), 4);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn mean_batch_size() {
        let s = Stats::new();
        s.batches.store(2, Ordering::Relaxed);
        s.batched_items.store(7, Ordering::Relaxed);
        assert!((s.mean_batch_size() - 3.5).abs() < 1e-12);
    }
}
