//! Zero-copy map artifacts: one page-aligned, read-only byte region
//! backing every weight a sampled map owns.
//!
//! The paper's maps are sampled once and read forever, so the crate's
//! serving tier should never pay a per-tenant copy of weight state.
//! This module gives weights a single owner — a [`MapArtifact`]: an
//! `Arc`-backed, 4096-byte-aligned allocation whose internal section
//! layout matches the typed views (`&[f32]`, `&[u32]`, `&[u64]`) the
//! transform hot paths read — and lets every layer above it *borrow*:
//!
//! * [`WeightStore<T>`] is the ownership seam. Sampling produces
//!   `Owned` stores (an `Arc<[T]>`); loading an artifact produces
//!   `Artifact` stores (an offset/length view into the shared region).
//!   `RademacherMatrix`, `StructuredProjection` and `RandomMaclaurin`
//!   hold `WeightStore`s and are bitwise-identical either way.
//! * The `RFDM0003` container is the on-disk twin of the in-memory
//!   layout: little-endian header, a section table, then 8-byte-aligned
//!   sections. Loading is header-validate + **one** `memcpy` into one
//!   aligned allocation (mmap-ready: the offsets in the table are the
//!   offsets in memory). `tests/alloc_free_transform.rs` pins the
//!   one-payload-allocation contract with a counting allocator.
//! * `RFDM0001` (dense) and `RFDM0002` (structured, seed-only) records
//!   are transparently up-converted on read, so old blobs keep loading.
//!
//! Randomness recycling (Choromanski & Sindhwani, *Recycling Randomness
//! with Structure*) rides on the same seam: with `RmConfig::recycle`
//! (CLI `--recycle`, default **off**), the HD/Fastfood chains draw
//! their per-block Rademacher/Gaussian state as *views into one shared
//! pool* instead of independent samples. The serializer interns backing
//! storage by identity, so a recycled stack stores each pool once —
//! state shrinks toward `O(d)` while every block's marginal law is
//! exactly the fresh-sample law (see ARCHITECTURE.md for the argument).
//! Default-off numerics are bit-identical to the unrecycled build.

use crate::maclaurin::{serialize, RandomMaclaurin, RmConfig};
use crate::rng::RademacherMatrix;
use crate::structured::hd::HdBlock;
use crate::structured::{ProjectionKind, StructuredProjection};
use crate::{obs, Error, Result};
use std::path::Path;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Magic for the zero-copy container format.
pub const MAGIC_V3: &[u8; 8] = b"RFDM0003";

const FLAG_STRUCTURED: u32 = 1;
const FLAG_RECYCLED: u32 = 2;

/// Sections start (and end, via zero padding) on 8-byte boundaries so
/// a `u64` view is always aligned inside the page-aligned region.
const SEC_ALIGN: usize = 8;

/// Fixed byte count of the v3 header before the kernel name.
const HEADER_BYTES: usize = 56;

const SEC_ORDERS: u32 = 1;
const SEC_WEIGHTS: u32 = 2;
const SEC_OFFSETS: u32 = 3;
const SEC_WORDS: u32 = 4;
const SEC_SCALES: u32 = 5;
const SEC_BLOCKS: u32 = 6;
const SEC_SIGNS: u32 = 7;
const SEC_PERMS: u32 = 8;
const SEC_GAINS: u32 = 9;
const SEC_TAPS: u32 = 10;

/// `u32`s per block in the `BLOCKS` descriptor section:
/// `[signs_off, has_perm_gain, perm_off, gain_off, taps_off, n_taps]`.
const BLOCK_WORDS: usize = 6;

/// Canonical section sequences (dense / structured records).
const DENSE_SECTIONS: [u32; 4] = [SEC_ORDERS, SEC_WEIGHTS, SEC_OFFSETS, SEC_WORDS];
const STRUCTURED_SECTIONS: [u32; 9] = [
    SEC_ORDERS,
    SEC_WEIGHTS,
    SEC_OFFSETS,
    SEC_SCALES,
    SEC_BLOCKS,
    SEC_SIGNS,
    SEC_PERMS,
    SEC_GAINS,
    SEC_TAPS,
];

const MAX_SECTIONS: usize = STRUCTURED_SECTIONS.len();

fn sec_name(kind: u32) -> &'static str {
    match kind {
        SEC_ORDERS => "orders",
        SEC_WEIGHTS => "weights",
        SEC_OFFSETS => "offsets",
        SEC_WORDS => "words",
        SEC_SCALES => "scales",
        SEC_BLOCKS => "blocks",
        SEC_SIGNS => "signs",
        SEC_PERMS => "perms",
        SEC_GAINS => "gains",
        SEC_TAPS => "taps",
        _ => "unknown",
    }
}

fn sec_elem_size(kind: u32) -> usize {
    match kind {
        SEC_WORDS => 8,
        _ => 4,
    }
}

fn align8(n: usize) -> usize {
    n.div_ceil(SEC_ALIGN) * SEC_ALIGN
}

fn data_err(msg: impl Into<String>) -> Error {
    Error::Data(msg.into())
}

// ---------------------------------------------------------------------------
// Resident-byte accounting (obs wiring for the load paths).

static RESIDENT_BYTES: AtomicI64 = AtomicI64::new(0);

fn resident_add(delta: i64) {
    let now = RESIDENT_BYTES.fetch_add(delta, Ordering::Relaxed) + delta;
    obs::gauge("artifact.bytes").set(now);
}

/// Bytes currently held by live artifact regions (mirrors the
/// `artifact.bytes` gauge; exposed for the bench sweep).
pub fn resident_bytes() -> i64 {
    RESIDENT_BYTES.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// AlignedBytes: the single allocation behind an artifact.

/// A page-aligned, immutable byte region. One of these backs every
/// [`MapArtifact`]; all typed weight views borrow from it through an
/// `Arc`, so N workers / tenants share one copy of the weights.
pub struct AlignedBytes {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the region is written once at construction and never mutated
// afterwards; `&AlignedBytes` only hands out shared `&[u8]` views.
unsafe impl Send for AlignedBytes {}
unsafe impl Sync for AlignedBytes {}

impl AlignedBytes {
    /// Allocation alignment: one page, so an eventual `mmap` swap-in
    /// needs no layout change and every section view is aligned.
    pub const ALIGN: usize = 4096;

    fn layout(len: usize) -> std::alloc::Layout {
        // Zero-length regions still get a real (1-byte) allocation so
        // the pointer is never dangling.
        std::alloc::Layout::from_size_align(len.max(1), Self::ALIGN)
            .expect("artifact region layout")
    }

    /// One allocation + one `memcpy` of `src`.
    pub(crate) fn copy_from(src: &[u8]) -> AlignedBytes {
        let layout = Self::layout(src.len());
        // SAFETY: `layout` has non-zero size by construction.
        let raw = unsafe { std::alloc::alloc(layout) };
        let Some(ptr) = std::ptr::NonNull::new(raw) else {
            std::alloc::handle_alloc_error(layout);
        };
        // SAFETY: freshly allocated region of at least `src.len()`
        // bytes; the ranges cannot overlap.
        unsafe { std::ptr::copy_nonoverlapping(src.as_ptr(), ptr.as_ptr(), src.len()) };
        resident_add(src.len() as i64);
        AlignedBytes { ptr, len: src.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: `ptr` is valid for `len` bytes for the lifetime of
        // `self` and never written after construction.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedBytes {
    fn drop(&mut self) {
        resident_add(-(self.len as i64));
        // SAFETY: allocated in `copy_from` with this exact layout.
        unsafe { std::alloc::dealloc(self.ptr.as_ptr(), Self::layout(self.len)) };
    }
}

impl std::fmt::Debug for AlignedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBytes({} bytes)", self.len)
    }
}

// ---------------------------------------------------------------------------
// WeightStore: the ownership seam.

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for u32 {}
    impl Sealed for u64 {}
}

/// Element types a [`WeightStore`] may hold. Sealed to the three plain
/// little-endian scalars the container stores (every bit pattern of
/// each is a valid value, which the artifact-backed view relies on).
pub trait Scalar:
    Copy + PartialEq + Send + Sync + std::fmt::Debug + sealed::Sealed + 'static
{
}

impl Scalar for f32 {}
impl Scalar for u32 {}
impl Scalar for u64 {}

#[derive(Clone)]
enum Backing<T: Scalar> {
    /// Sampled in-process; shared by refcount when cloned.
    Owned(Arc<[T]>),
    /// A section of a loaded artifact region: `total` elements of `T`
    /// starting `base` bytes into `bytes` (alignment and bounds
    /// validated at construction).
    Artifact { bytes: Arc<AlignedBytes>, base: usize, total: usize },
}

/// Read-only weight storage: either owned (sampling) or a view into a
/// shared [`MapArtifact`] region (loading). Cloning never copies the
/// elements, and sub-[`view`](WeightStore::view)s share the backing —
/// which is what lets randomness recycling alias one pool from many
/// blocks at zero marginal cost.
#[derive(Clone)]
pub struct WeightStore<T: Scalar> {
    backing: Backing<T>,
    off: usize,
    len: usize,
}

impl<T: Scalar> WeightStore<T> {
    /// Owned store over freshly sampled values.
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        WeightStore { backing: Backing::Owned(v.into()), off: 0, len }
    }

    /// A view of `len` elements at `off` *of the shared backing* (not
    /// relative to `self`'s own window). Views alias: two views of one
    /// store share storage byte-for-byte.
    pub fn view(&self, off: usize, len: usize) -> Self {
        let total = self.backing_slice().len();
        assert!(
            off.checked_add(len).is_some_and(|end| end <= total),
            "weight view [{off}, {off}+{len}) out of bounds for backing of {total}"
        );
        WeightStore { backing: self.backing.clone(), off, len }
    }

    /// Artifact-backed view: `total` elements at byte offset `base` of
    /// `bytes`, windowed to `[off, off + len)`. Validates alignment and
    /// bounds once; `as_slice` is then branch-free.
    pub(crate) fn artifact_view(
        bytes: &Arc<AlignedBytes>,
        base: usize,
        total: usize,
        off: usize,
        len: usize,
    ) -> Result<Self> {
        let esize = std::mem::size_of::<T>();
        let end = total
            .checked_mul(esize)
            .and_then(|b| base.checked_add(b))
            .ok_or_else(|| data_err("artifact section size overflows"))?;
        if end > bytes.len() {
            return Err(data_err(format!(
                "artifact section [{base}, {end}) out of bounds for region of {}",
                bytes.len()
            )));
        }
        if base % std::mem::align_of::<T>() != 0 {
            return Err(data_err(format!("artifact section at byte {base} is misaligned")));
        }
        if off.checked_add(len).is_none_or(|e| e > total) {
            return Err(data_err("artifact weight view out of bounds"));
        }
        Ok(WeightStore {
            backing: Backing::Artifact { bytes: bytes.clone(), base, total },
            off,
            len,
        })
    }

    /// The full shared backing (a recycled pool is larger than any one
    /// view of it).
    #[inline]
    pub(crate) fn backing_slice(&self) -> &[T] {
        match &self.backing {
            Backing::Owned(v) => v,
            Backing::Artifact { bytes, base, total } => {
                // SAFETY: `base`/`total` were bounds- and alignment-
                // checked against the immutable region in
                // `artifact_view`, and `T` (sealed) admits every bit
                // pattern.
                unsafe {
                    std::slice::from_raw_parts(
                        bytes.as_slice().as_ptr().add(*base) as *const T,
                        *total,
                    )
                }
            }
        }
    }

    /// This store's window of the backing.
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.backing_slice()[self.off..self.off + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Element offset of this view inside its backing.
    pub(crate) fn view_off(&self) -> usize {
        self.off
    }

    /// Stable identity of the backing storage — equal iff two stores
    /// alias the same bytes. The serializer interns pools by this key,
    /// which is how recycled stacks dedupe to one stored copy.
    pub(crate) fn backing_id(&self) -> usize {
        match &self.backing {
            Backing::Owned(v) => v.as_ptr() as usize,
            Backing::Artifact { bytes, base, .. } => bytes.as_slice().as_ptr() as usize + *base,
        }
    }

    /// True when this store borrows from a loaded artifact region.
    pub fn is_artifact_backed(&self) -> bool {
        matches!(self.backing, Backing::Artifact { .. })
    }
}

impl<T: Scalar> From<Vec<T>> for WeightStore<T> {
    fn from(v: Vec<T>) -> Self {
        WeightStore::from_vec(v)
    }
}

impl<T: Scalar> PartialEq for WeightStore<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Scalar> std::fmt::Debug for WeightStore<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match &self.backing {
            Backing::Owned(_) => "owned",
            Backing::Artifact { .. } => "artifact",
        };
        write!(f, "WeightStore<{kind}>[{}; off {}]", self.len, self.off)
    }
}

// ---------------------------------------------------------------------------
// MapArtifact.

/// One parsed section: `elems` elements of the section's scalar type at
/// `byte_off` inside the region.
#[derive(Clone, Copy, Debug, Default)]
struct Section {
    kind: u32,
    byte_off: usize,
    elems: usize,
}

/// A loaded (or freshly encoded) map in `RFDM0003` form: the validated
/// header plus one shared read-only byte region holding every weight.
/// `instantiate()` builds a [`RandomMaclaurin`] whose stores *borrow*
/// from this region; cloning the map or handing it to more workers
/// never copies weights.
#[derive(Clone, Debug)]
pub struct MapArtifact {
    bytes: Arc<AlignedBytes>,
    d: usize,
    n_random: usize,
    rows: usize,
    p: f64,
    h01: bool,
    max_order: u32,
    w_const: f32,
    w_linear: f32,
    proj_seed: u64,
    structured: bool,
    recycled: bool,
    /// Kernel name as a `(byte_off, byte_len)` range into the region
    /// (validated UTF-8), so parsing allocates nothing per-field.
    kname: (usize, usize),
    nsec: usize,
    sections: [Section; MAX_SECTIONS],
}

/// Human-readable description of one section (for `rfdot map-info`).
#[derive(Clone, Debug)]
pub struct SectionInfo {
    pub name: &'static str,
    pub elems: usize,
    pub bytes: usize,
    pub byte_off: usize,
}

/// Header + sizing summary (for `rfdot map-info` and the bench sweep).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub kind: &'static str,
    pub recycled: bool,
    pub d: usize,
    pub n_random: usize,
    pub rows: usize,
    pub p: f64,
    pub h01: bool,
    pub max_order: u32,
    pub kernel: String,
    pub proj_seed: u64,
    /// Total container size (header + table + sections).
    pub total_bytes: usize,
    /// Weight bytes actually stored (recycled pools counted once).
    pub stored_weight_bytes: u64,
    /// Weight bytes a per-tenant owned copy would pay (recycled views
    /// counted at expanded size) — the "bytes per tenant" an artifact
    /// amortizes away.
    pub expanded_weight_bytes: u64,
    pub sections: Vec<SectionInfo>,
}

impl MapArtifact {
    /// Parse any RFDM record. `RFDM0003` is validated in place and
    /// copied once into an aligned region; `RFDM0001`/`0002` records
    /// are up-converted (decode via the legacy reader, re-encode as
    /// v3) so every load path lands on the same zero-copy layout.
    pub fn from_bytes(buf: &[u8]) -> Result<MapArtifact> {
        if buf.len() >= 8 && &buf[..8] == MAGIC_V3 {
            let art = Self::parse_v3(buf)?;
            obs::counter("artifact.loads").add(1);
            return Ok(art);
        }
        // Legacy records: the serialize module rejects malformed input,
        // then the round-trip through `encode` preserves bit-identity
        // (`instantiate().transform(x)` equals the legacy map's).
        let map = serialize::from_bytes(buf)?;
        let art = Self::parse_v3(&Self::encode(&map))?;
        obs::counter("artifact.loads").add(1);
        Ok(art)
    }

    /// Encode a sampled map and re-load it as a shared artifact.
    pub fn from_map(map: &RandomMaclaurin) -> Result<MapArtifact> {
        Self::from_bytes(&Self::encode(map))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<MapArtifact> {
        crate::faults::failpoint("artifact.load")?;
        let mut buf = std::fs::read(path)?;
        // Chaos site: a torn or bit-flipped read surfaces here exactly
        // as it would from failing storage — the parser below must turn
        // it into a named error, never a panic.
        crate::faults::mangle("artifact.read", &mut buf)?;
        Self::from_bytes(&buf)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.as_bytes())?;
        Ok(())
    }

    /// The full container bytes (re-encoding a loaded artifact is
    /// byte-identical: the region *is* the serialized form).
    pub fn as_bytes(&self) -> &[u8] {
        self.bytes.as_slice()
    }

    pub fn input_dim(&self) -> usize {
        self.d
    }

    pub fn n_random(&self) -> usize {
        self.n_random
    }

    pub fn is_structured(&self) -> bool {
        self.structured
    }

    pub fn is_recycled(&self) -> bool {
        self.recycled
    }

    pub fn total_bytes(&self) -> usize {
        self.bytes.len()
    }

    pub fn kernel_name(&self) -> &str {
        let (off, len) = self.kname;
        std::str::from_utf8(&self.bytes.as_slice()[off..off + len]).expect("validated at parse")
    }

    fn section_index(&self, kind: u32) -> Option<usize> {
        self.sections[..self.nsec].iter().position(|s| s.kind == kind)
    }

    /// Typed view of section `i`. Alignment/bounds hold by parse-time
    /// validation; callers pass the `T` matching the section kind.
    fn section<T: Scalar>(&self, i: usize) -> &[T] {
        let s = self.sections[i];
        debug_assert_eq!(sec_elem_size(s.kind), std::mem::size_of::<T>());
        // SAFETY: byte_off/elems validated against the immutable region
        // in `parse_v3`; sections are 8-byte aligned; `T` is sealed to
        // types where every bit pattern is valid.
        unsafe {
            std::slice::from_raw_parts(
                self.bytes.as_slice().as_ptr().add(s.byte_off) as *const T,
                s.elems,
            )
        }
    }

    fn store<T: Scalar>(&self, i: usize, off: usize, len: usize) -> Result<WeightStore<T>> {
        let s = self.sections[i];
        WeightStore::artifact_view(&self.bytes, s.byte_off, s.elems, off, len)
    }

    // -- parsing ----------------------------------------------------------

    fn parse_v3(buf: &[u8]) -> Result<MapArtifact> {
        // Same chaos site as the legacy serialize reader: both are
        // "RFDM decode", whichever container generation is on disk.
        crate::faults::failpoint("rfdm.decode")?;
        let mut r = serialize::Reader::new(buf);
        if r.take(8)? != MAGIC_V3 {
            return Err(data_err("bad magic in RFDM0003 blob"));
        }
        let flags = r.u32()?;
        if flags & !(FLAG_STRUCTURED | FLAG_RECYCLED) != 0 {
            return Err(data_err(format!("unknown RFDM0003 flags {flags:#x}")));
        }
        let structured = flags & FLAG_STRUCTURED != 0;
        let recycled = flags & FLAG_RECYCLED != 0;
        if recycled && !structured {
            return Err(data_err("RFDM0003 recycled flag on a dense record"));
        }
        let d = r.u32()? as usize;
        let n_random = r.u32()? as usize;
        let p = r.f64()?;
        let h01_byte = r.take(1)?[0];
        if h01_byte > 1 {
            return Err(data_err("non-canonical h01 byte in RFDM0003 header"));
        }
        if r.take(3)? != [0u8; 3] {
            return Err(data_err("non-zero header padding in RFDM0003 blob"));
        }
        let max_order = r.u32()?;
        let w_const = r.f32()?;
        let w_linear = r.f32()?;
        let proj_seed = r.u64()?;
        if d == 0 || n_random == 0 || !(p > 1.0) {
            return Err(data_err("invalid RFDM0003 header"));
        }
        let klen = r.u32()? as usize;
        debug_assert_eq!(r.pos(), HEADER_BYTES);
        let kname_off = r.pos();
        let kbytes = r.take(klen)?;
        if std::str::from_utf8(kbytes).is_err() {
            return Err(data_err("kernel name in RFDM0003 blob is not UTF-8"));
        }
        let pad = (SEC_ALIGN - r.pos() % SEC_ALIGN) % SEC_ALIGN;
        if r.take(pad)?.iter().any(|&b| b != 0) {
            return Err(data_err("non-zero kernel-name padding in RFDM0003 blob"));
        }
        let nsec = r.u32()? as usize;
        if r.u32()? != 0 {
            return Err(data_err("non-zero section-count padding in RFDM0003 blob"));
        }
        let expected: &[u32] =
            if structured { &STRUCTURED_SECTIONS } else { &DENSE_SECTIONS };
        if nsec != expected.len() {
            return Err(data_err(format!(
                "RFDM0003 section count {nsec} does not match record kind"
            )));
        }
        let mut sections = [Section::default(); MAX_SECTIONS];
        for (i, sec) in sections.iter_mut().take(nsec).enumerate() {
            let kind = r.u32()?;
            if r.u32()? != 0 {
                return Err(data_err("non-zero section-entry padding in RFDM0003 blob"));
            }
            let byte_off = usize::try_from(r.u64()?)
                .map_err(|_| data_err("RFDM0003 section offset overflows"))?;
            let elems = usize::try_from(r.u64()?)
                .map_err(|_| data_err("RFDM0003 section length overflows"))?;
            if kind != expected[i] {
                return Err(data_err(format!(
                    "unexpected RFDM0003 section kind {kind} at index {i} (want {})",
                    expected[i]
                )));
            }
            *sec = Section { kind, byte_off, elems };
        }
        // Canonical layout: each section starts where the previous one
        // (8-aligned, zero-padded) ended, and the blob ends exactly at
        // the padded end of the last section. This makes the encoding
        // injective — re-encode of a parse is byte-identical.
        let mut cursor = r.pos();
        debug_assert_eq!(cursor % SEC_ALIGN, 0);
        for sec in &sections[..nsec] {
            if sec.byte_off != cursor {
                return Err(data_err(format!(
                    "non-canonical RFDM0003 section offset {} (want {cursor})",
                    sec.byte_off
                )));
            }
            let byte_len = sec
                .elems
                .checked_mul(sec_elem_size(sec.kind))
                .ok_or_else(|| data_err("RFDM0003 section size overflows"))?;
            let end = cursor
                .checked_add(byte_len)
                .ok_or_else(|| data_err("RFDM0003 section size overflows"))?;
            if end > buf.len() {
                return Err(data_err("truncated RFDM0003 section payload"));
            }
            let padded = align8(end);
            if padded > buf.len() {
                return Err(data_err("truncated RFDM0003 section padding"));
            }
            if buf[end..padded].iter().any(|&b| b != 0) {
                return Err(data_err("non-zero RFDM0003 section padding"));
            }
            cursor = padded;
        }
        if cursor != buf.len() {
            return Err(data_err("trailing bytes in RFDM0003 blob"));
        }

        // One allocation, one copy: the region is the blob.
        let bytes = Arc::new(AlignedBytes::copy_from(buf));
        let art = MapArtifact {
            bytes,
            d,
            n_random,
            rows: 0,
            p,
            h01: h01_byte == 1,
            max_order,
            w_const,
            w_linear,
            proj_seed,
            structured,
            recycled,
            kname: (kname_off, klen),
            nsec,
            sections,
        };
        art.validate_content()
    }

    /// Cross-field validation of section contents (runs on the aligned
    /// copy; every read below is bounds-checked by the section table
    /// validation above). Returns `self` with `rows` filled in.
    fn validate_content(mut self) -> Result<MapArtifact> {
        let d = self.d;
        let n_random = self.n_random;
        let orders: &[u32] = self.section(0);
        let weights: &[f32] = self.section(1);
        let offsets: &[u32] = self.section(2);
        if orders.len() != n_random || weights.len() != n_random {
            return Err(data_err("RFDM0003 orders/weights length does not match n_random"));
        }
        if offsets.len() != n_random + 1 {
            return Err(data_err("RFDM0003 offsets length is not n_random + 1"));
        }
        if offsets[0] != 0 {
            return Err(data_err("RFDM0003 offsets do not start at zero"));
        }
        for i in 0..n_random {
            if orders[i] > self.max_order {
                return Err(data_err(format!(
                    "RFDM0003 order {} exceeds max_order {}",
                    orders[i], self.max_order
                )));
            }
            if u64::from(offsets[i]) + u64::from(orders[i]) != u64::from(offsets[i + 1]) {
                return Err(data_err("RFDM0003 offsets are not the running order sum"));
            }
        }
        let rows = offsets[n_random] as usize;
        self.rows = rows;

        if self.structured {
            let n = crate::linalg::next_pow2(d);
            let scales_i = self.section_index(SEC_SCALES).expect("layout checked");
            let n_blocks = self.sections[scales_i].elems;
            let blocks: &[u32] = self.section(self.section_index(SEC_BLOCKS).expect("layout"));
            if blocks.len() != n_blocks * BLOCK_WORDS {
                return Err(data_err("RFDM0003 blocks section length mismatch"));
            }
            let signs_len = self.sections[self.section_index(SEC_SIGNS).expect("layout")].elems;
            let perms_i = self.section_index(SEC_PERMS).expect("layout");
            let perms_len = self.sections[perms_i].elems;
            let gains_len = self.sections[self.section_index(SEC_GAINS).expect("layout")].elems;
            let taps_i = self.section_index(SEC_TAPS).expect("layout");
            let taps_len = self.sections[taps_i].elems;
            let perms: &[u32] = self.section(perms_i);
            let taps: &[u32] = self.section(taps_i);
            let fits = |off: u32, len: usize, total: usize| (off as usize) + len <= total;
            for b in 0..n_blocks {
                let desc = &blocks[b * BLOCK_WORDS..(b + 1) * BLOCK_WORDS];
                let [s_off, has_pg, p_off, g_off, t_off, n_taps] =
                    [desc[0], desc[1], desc[2], desc[3], desc[4], desc[5]];
                if !fits(s_off, n, signs_len) {
                    return Err(data_err("RFDM0003 block signs view out of bounds"));
                }
                match has_pg {
                    0 => {
                        if p_off != 0 || g_off != 0 {
                            return Err(data_err(
                                "non-canonical RFDM0003 block without perm/gain",
                            ));
                        }
                    }
                    1 => {
                        if !fits(p_off, n, perms_len) || !fits(g_off, n, gains_len) {
                            return Err(data_err(
                                "RFDM0003 block perm/gain view out of bounds",
                            ));
                        }
                        let pv = &perms[p_off as usize..p_off as usize + n];
                        if pv.iter().any(|&x| x as usize >= n) {
                            return Err(data_err("RFDM0003 permutation entry out of range"));
                        }
                    }
                    _ => return Err(data_err("invalid RFDM0003 block perm/gain flag")),
                }
                let t_len = (n_taps as usize)
                    .checked_mul(2)
                    .ok_or_else(|| data_err("RFDM0003 tap count overflows"))?;
                if !fits(t_off, t_len, taps_len) {
                    return Err(data_err("RFDM0003 block taps view out of bounds"));
                }
                let tv = &taps[t_off as usize..t_off as usize + t_len];
                for t in tv.chunks_exact(2) {
                    if t[0] as usize >= n {
                        return Err(data_err("RFDM0003 tap slot out of range"));
                    }
                    if t[1] as usize >= rows {
                        return Err(data_err("RFDM0003 tap row out of range"));
                    }
                }
            }
        } else {
            let words_i = self.section_index(SEC_WORDS).expect("layout checked");
            let expect = rows
                .checked_mul(d.div_ceil(64))
                .ok_or_else(|| data_err("RFDM0003 word count overflows"))?;
            if self.sections[words_i].elems != expect {
                return Err(data_err(format!(
                    "RFDM0003 words length {} does not match rows {rows} × dim {d}",
                    self.sections[words_i].elems
                )));
            }
        }
        Ok(self)
    }

    // -- instantiation ----------------------------------------------------

    /// Build a [`RandomMaclaurin`] whose every weight store borrows
    /// from this artifact's shared region. Infallible modulo the
    /// validation already performed at parse; cheap (no weight copies —
    /// the counting-allocator test pins this).
    pub fn instantiate(&self) -> Result<RandomMaclaurin> {
        let orders = self.store::<u32>(0, 0, self.n_random)?;
        let weights = self.store::<f32>(1, 0, self.n_random)?;
        let offsets = self.store::<u32>(2, 0, self.n_random + 1)?;
        let projection =
            if self.structured { ProjectionKind::Structured } else { ProjectionKind::Dense };
        let config = RmConfig::default()
            .with_p(self.p)
            .with_h01(self.h01)
            .with_max_order(self.max_order)
            .with_projection(projection)
            .with_recycle(self.recycled);
        let (omegas, structured) = if self.structured {
            let n = crate::linalg::next_pow2(self.d);
            let scales_i = self.section_index(SEC_SCALES).expect("layout");
            let blocks_i = self.section_index(SEC_BLOCKS).expect("layout");
            let signs_i = self.section_index(SEC_SIGNS).expect("layout");
            let perms_i = self.section_index(SEC_PERMS).expect("layout");
            let gains_i = self.section_index(SEC_GAINS).expect("layout");
            let taps_i = self.section_index(SEC_TAPS).expect("layout");
            let n_blocks = self.sections[scales_i].elems;
            let scales: &[f32] = self.section(scales_i);
            let descs: &[u32] = self.section(blocks_i);
            let mut blocks = Vec::with_capacity(n_blocks);
            for b in 0..n_blocks {
                let desc = &descs[b * BLOCK_WORDS..(b + 1) * BLOCK_WORDS];
                let signs = self.store::<f32>(signs_i, desc[0] as usize, n)?;
                let perm_gain = if desc[1] == 1 {
                    Some((
                        self.store::<u32>(perms_i, desc[2] as usize, n)?,
                        self.store::<f32>(gains_i, desc[3] as usize, n)?,
                    ))
                } else {
                    None
                };
                let taps =
                    self.store::<u32>(taps_i, desc[4] as usize, desc[5] as usize * 2)?;
                blocks.push(HdBlock { signs, perm_gain, taps, scale: scales[b] });
            }
            let proj = StructuredProjection::from_blocks(self.d, self.rows, blocks);
            (RademacherMatrix::from_words(0, self.d, Vec::new()), Some(proj))
        } else {
            let words_i = self.section_index(SEC_WORDS).expect("layout");
            let words = self.store::<u64>(words_i, 0, self.sections[words_i].elems)?;
            (RademacherMatrix::from_store(self.rows, self.d, words), None)
        };
        Ok(RandomMaclaurin::from_artifact_parts(
            self.d,
            self.n_random,
            config,
            orders,
            weights,
            offsets,
            omegas,
            structured,
            self.proj_seed,
            self.w_const,
            self.w_linear,
            self.kernel_name().to_string(),
        ))
    }

    // -- encoding ---------------------------------------------------------

    /// Serialize a map into the v3 container. Deterministic; pools are
    /// interned by backing identity, so recycled stacks (and re-encodes
    /// of artifact-backed maps, which alias one region) store each
    /// shared pool exactly once.
    pub fn encode(map: &RandomMaclaurin) -> Vec<u8> {
        let structured = map.is_structured();
        let recycled = structured && map.config().recycle;
        let mut flags = 0u32;
        if structured {
            flags |= FLAG_STRUCTURED;
        }
        if recycled {
            flags |= FLAG_RECYCLED;
        }
        let kname = map.kernel_name().as_bytes();

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V3);
        put_u32(&mut out, flags);
        put_u32(&mut out, map.input_dim() as u32);
        put_u32(&mut out, map.n_random() as u32);
        out.extend_from_slice(&map.config().p.to_le_bytes());
        out.push(map.config().h01 as u8);
        out.extend_from_slice(&[0u8; 3]);
        put_u32(&mut out, map.config().max_order);
        out.extend_from_slice(&map.w_const().to_le_bytes());
        out.extend_from_slice(&map.w_linear().to_le_bytes());
        out.extend_from_slice(&map.proj_seed().to_le_bytes());
        put_u32(&mut out, kname.len() as u32);
        debug_assert_eq!(out.len(), HEADER_BYTES);
        out.extend_from_slice(kname);
        while out.len() % SEC_ALIGN != 0 {
            out.push(0);
        }

        // Gather section payloads.
        enum SecData {
            U32(Vec<u32>),
            F32(Vec<f32>),
            U64(Vec<u64>),
        }
        impl SecData {
            fn elems(&self) -> usize {
                match self {
                    SecData::U32(v) => v.len(),
                    SecData::F32(v) => v.len(),
                    SecData::U64(v) => v.len(),
                }
            }
            fn write(&self, out: &mut Vec<u8>) {
                match self {
                    SecData::U32(v) => v.iter().for_each(|x| put_u32(out, *x)),
                    SecData::F32(v) => {
                        v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes()))
                    }
                    SecData::U64(v) => {
                        v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes()))
                    }
                }
            }
        }
        let mut secs: Vec<(u32, SecData)> = vec![
            (SEC_ORDERS, SecData::U32(map.orders().to_vec())),
            (SEC_WEIGHTS, SecData::F32(map.weights().to_vec())),
            (SEC_OFFSETS, SecData::U32(map.offsets().to_vec())),
        ];
        if structured {
            let proj = map
                .structured_projection()
                .expect("structured map carries a projection");
            let mut scales = Vec::new();
            let mut descs: Vec<u32> = Vec::new();
            let mut signs_pool: Vec<f32> = Vec::new();
            let mut perms_pool: Vec<u32> = Vec::new();
            let mut gains_pool: Vec<f32> = Vec::new();
            let mut taps_pool: Vec<u32> = Vec::new();
            // Interning tables: backing identity → element base in the
            // pool section. Aliased stores serialize once.
            let mut seen_signs = std::collections::HashMap::new();
            let mut seen_perms = std::collections::HashMap::new();
            let mut seen_gains = std::collections::HashMap::new();
            let mut seen_taps = std::collections::HashMap::new();
            fn intern<T: Scalar>(
                pool: &mut Vec<T>,
                seen: &mut std::collections::HashMap<usize, usize>,
                store: &WeightStore<T>,
            ) -> u32 {
                let base = *seen.entry(store.backing_id()).or_insert_with(|| {
                    let at = pool.len();
                    pool.extend_from_slice(store.backing_slice());
                    at
                });
                u32::try_from(base + store.view_off()).expect("pool offset fits u32")
            }
            for block in proj.blocks() {
                scales.push(block.scale);
                let s_off = intern(&mut signs_pool, &mut seen_signs, &block.signs);
                let (has_pg, p_off, g_off) = match &block.perm_gain {
                    Some((perm, gain)) => (
                        1,
                        intern(&mut perms_pool, &mut seen_perms, perm),
                        intern(&mut gains_pool, &mut seen_gains, gain),
                    ),
                    None => (0, 0, 0),
                };
                let t_off = intern(&mut taps_pool, &mut seen_taps, &block.taps);
                let n_taps = u32::try_from(block.taps.len() / 2).expect("tap count fits u32");
                descs.extend_from_slice(&[s_off, has_pg, p_off, g_off, t_off, n_taps]);
            }
            secs.push((SEC_SCALES, SecData::F32(scales)));
            secs.push((SEC_BLOCKS, SecData::U32(descs)));
            secs.push((SEC_SIGNS, SecData::F32(signs_pool)));
            secs.push((SEC_PERMS, SecData::U32(perms_pool)));
            secs.push((SEC_GAINS, SecData::F32(gains_pool)));
            secs.push((SEC_TAPS, SecData::U32(taps_pool)));
        } else {
            secs.push((SEC_WORDS, SecData::U64(map.omegas().words().to_vec())));
        }

        // Section table, then 8-aligned payloads.
        put_u32(&mut out, secs.len() as u32);
        put_u32(&mut out, 0);
        let mut cursor = out.len() + secs.len() * 24;
        debug_assert_eq!(cursor % SEC_ALIGN, 0);
        for (kind, data) in &secs {
            put_u32(&mut out, *kind);
            put_u32(&mut out, 0);
            out.extend_from_slice(&(cursor as u64).to_le_bytes());
            out.extend_from_slice(&(data.elems() as u64).to_le_bytes());
            cursor = align8(cursor + data.elems() * sec_elem_size(*kind));
        }
        for (_, data) in &secs {
            data.write(&mut out);
            while out.len() % SEC_ALIGN != 0 {
                out.push(0);
            }
        }
        debug_assert_eq!(out.len(), cursor);
        out
    }

    // -- reporting --------------------------------------------------------

    pub fn info(&self) -> ArtifactInfo {
        let mut sections = Vec::with_capacity(self.nsec);
        let mut stored = 0u64;
        for s in &self.sections[..self.nsec] {
            let bytes = s.elems * sec_elem_size(s.kind);
            stored += bytes as u64;
            sections.push(SectionInfo {
                name: sec_name(s.kind),
                elems: s.elems,
                bytes,
                byte_off: s.byte_off,
            });
        }
        ArtifactInfo {
            kind: if self.structured { "structured" } else { "dense" },
            recycled: self.recycled,
            d: self.d,
            n_random: self.n_random,
            rows: self.rows,
            p: self.p,
            h01: self.h01,
            max_order: self.max_order,
            kernel: self.kernel_name().to_string(),
            proj_seed: self.proj_seed,
            total_bytes: self.total_bytes(),
            stored_weight_bytes: stored,
            expanded_weight_bytes: self.expanded_weight_bytes(),
            sections,
        }
    }

    /// Weight bytes an *owned* copy of this map would hold: every block
    /// view counted at its expanded size, shared pools multiply. The
    /// gap to `stored_weight_bytes` is what recycling + sharing saves
    /// per tenant.
    pub fn expanded_weight_bytes(&self) -> u64 {
        let base = (self.n_random * 4 + self.n_random * 4 + (self.n_random + 1) * 4) as u64;
        if !self.structured {
            let words_i = self.section_index(SEC_WORDS).expect("layout");
            return base + self.sections[words_i].elems as u64 * 8;
        }
        let n = crate::linalg::next_pow2(self.d) as u64;
        let blocks_i = self.section_index(SEC_BLOCKS).expect("layout");
        let descs: &[u32] = self.section(blocks_i);
        let mut total = base;
        for desc in descs.chunks_exact(BLOCK_WORDS) {
            total += n * 4; // signs
            if desc[1] == 1 {
                total += n * 4 + n * 4; // perm + gain
            }
            total += u64::from(desc[5]) * 2 * 4 + 4; // taps + scale
        }
        total
    }
}

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Exponential, Polynomial};
    use crate::maclaurin::FeatureMap;
    use crate::rng::Rng;

    fn sample_map(structured: bool, recycle: bool, seed: u64) -> RandomMaclaurin {
        let kind = if structured { ProjectionKind::Structured } else { ProjectionKind::Dense };
        RandomMaclaurin::sample(
            &Polynomial::new(4, 0.5),
            17,
            40,
            RmConfig::default().with_projection(kind).with_recycle(recycle),
            &mut Rng::seed_from(seed),
        )
    }

    fn probe(d: usize) -> Vec<f32> {
        (0..d).map(|k| ((k * 7 + 3) as f32 * 0.173).sin()).collect()
    }

    #[test]
    fn weight_store_views_alias_their_backing() {
        let store = WeightStore::from_vec(vec![1u32, 2, 3, 4, 5, 6]);
        let a = store.view(1, 3);
        let b = store.view(1, 3);
        assert_eq!(a.as_slice(), &[2, 3, 4]);
        assert_eq!(a.backing_id(), b.backing_id());
        assert_eq!(a, b);
        let shifted = store.view(3, 3);
        assert_eq!(shifted.as_slice(), &[4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn weight_store_view_rejects_overflow() {
        let store = WeightStore::from_vec(vec![0f32; 4]);
        let _ = store.view(3, 2);
    }

    #[test]
    fn aligned_bytes_are_page_aligned_and_tracked() {
        let before = resident_bytes();
        let region = AlignedBytes::copy_from(&[7u8; 100]);
        assert_eq!(region.as_slice().as_ptr() as usize % AlignedBytes::ALIGN, 0);
        assert_eq!(region.as_slice(), &[7u8; 100]);
        assert_eq!(resident_bytes(), before + 100);
        drop(region);
        assert_eq!(resident_bytes(), before);
    }

    #[test]
    fn v3_roundtrip_is_byte_identical_and_transform_exact() {
        for structured in [false, true] {
            let map = sample_map(structured, false, 99);
            let bytes = MapArtifact::encode(&map);
            let art = MapArtifact::from_bytes(&bytes).expect("parse own encoding");
            assert_eq!(art.as_bytes(), &bytes[..], "region is the serialized form");
            let thin = art.instantiate().expect("instantiate");
            let x = probe(17);
            assert_eq!(thin.transform(&x), map.transform(&x), "structured={structured}");
            // Re-encode of the artifact-backed map: byte-identical.
            assert_eq!(MapArtifact::encode(&thin), bytes);
        }
    }

    #[test]
    fn legacy_records_up_convert_bit_for_bit() {
        for structured in [false, true] {
            let map = sample_map(structured, false, 5);
            let legacy = serialize::to_bytes(&map);
            let art = MapArtifact::from_bytes(&legacy).expect("up-convert");
            let thin = art.instantiate().expect("instantiate");
            let x = probe(17);
            assert_eq!(thin.transform(&x), map.transform(&x), "structured={structured}");
        }
    }

    #[test]
    fn recycled_stack_stores_pools_once() {
        let plain = sample_map(true, false, 42);
        let recycled = sample_map(true, true, 42);
        let plain_bytes = MapArtifact::encode(&plain).len();
        let recycled_bytes = MapArtifact::encode(&recycled).len();
        assert!(
            recycled_bytes < plain_bytes,
            "recycling should shrink serialized structured state \
             ({recycled_bytes} vs {plain_bytes})"
        );
        // And the recycled record round-trips exactly.
        let art = MapArtifact::from_bytes(&MapArtifact::encode(&recycled)).unwrap();
        assert!(art.is_recycled());
        let x = probe(17);
        assert_eq!(art.instantiate().unwrap().transform(&x), recycled.transform(&x));
    }

    #[test]
    fn expanded_bytes_exceed_stored_bytes_for_recycled_maps() {
        let art = MapArtifact::from_map(&sample_map(true, true, 7)).unwrap();
        let info = art.info();
        assert!(
            info.expanded_weight_bytes > info.stored_weight_bytes,
            "recycled map-info must show savings: expanded {} stored {}",
            info.expanded_weight_bytes,
            info.stored_weight_bytes
        );
        let plain = MapArtifact::from_map(&sample_map(true, false, 7)).unwrap().info();
        assert_eq!(
            plain.expanded_weight_bytes, plain.stored_weight_bytes,
            "unrecycled structured maps store exactly their expanded state"
        );
    }

    #[test]
    fn rejects_malformed_v3_blobs() {
        let good = MapArtifact::encode(&sample_map(true, false, 3));
        assert!(MapArtifact::from_bytes(&good).is_ok());
        // Truncation anywhere must error, never panic.
        for cut in [4, 20, 57, good.len() / 2, good.len() - 1] {
            assert!(MapArtifact::from_bytes(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing bytes are non-canonical.
        let mut extra = good.clone();
        extra.extend_from_slice(&[0u8; 8]);
        assert!(MapArtifact::from_bytes(&extra).is_err());
        // Unknown flag bits are rejected.
        let mut flags = good.clone();
        flags[8] |= 0x80;
        assert!(MapArtifact::from_bytes(&flags).is_err());
    }

    #[test]
    fn artifact_loads_counter_ticks() {
        let c = obs::counter("artifact.loads");
        let before = c.get();
        let _ = MapArtifact::from_map(&sample_map(false, false, 1)).unwrap();
        assert!(c.get() > before);
    }
}
