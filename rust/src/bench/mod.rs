//! Benchmark harness (criterion is not reachable offline).
//!
//! Provides warmup + repeated timing with mean/stddev reporting, plus
//! the fixed-width table printer the paper-reproduction benches use to
//! emit Table 1 / Figure 1 / Figure 2 rows.

pub mod experiment;

pub use experiment::{
    run_exact, run_random_features, run_row, run_variant, CellResult, MapVariant, RowResult,
};

use crate::linalg::{mean, stddev};
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn stddev_s(&self) -> f64 {
        stddev(&self.samples)
    }

    /// Human-readable mean ± std.
    pub fn display(&self) -> String {
        format!("{} ± {}", fmt_duration(self.mean_s()), fmt_duration(self.stddev_s()))
    }
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), samples }
}

/// Time a single run (for end-to-end train/test timings where one run is
/// the experiment).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                let pad = w - cell.chars().count();
                line.push(' ');
                line.push_str(cell);
                line.push_str(&" ".repeat(pad + 1));
                line.push('|');
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 2, 5, || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
        assert!(!m.display().is_empty());
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0025), "2.50ms");
        assert_eq!(fmt_duration(2.5e-6), "2.50µs");
        assert_eq!(fmt_duration(5e-9), "5ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[1].starts_with("|---"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
