//! The Table 1 / Figure 2 experiment pipeline, shared by the CLI, the
//! bench harness and the examples.
//!
//! One "row" of the paper's Table 1 compares, on one dataset + kernel:
//!   * `K + SVM`    — exact kernel SVM (SMO; the LIBSVM column),
//!   * `RF + LIN`   — Random Maclaurin features + linear SVM,
//!   * `H0/1 + LIN` — the H0/1 variant at a smaller D.
//! reporting accuracy, train time and test time (feature construction
//! included in both, matching the paper's protocol).

use crate::config::{ExperimentConfig, KernelSpec};
use crate::data::{Dataset, UciSurrogate};
use crate::kernels::DotProductKernel;
use crate::features::FeatureMap;
use crate::maclaurin::{RandomMaclaurin, RmConfig};
use crate::metrics::Stopwatch;
use crate::nystrom::Nystrom;
use crate::rff::RandomFourier;
use crate::rng::Rng;
use crate::svm::{Classifier, KernelSvm, LinearSvm, LinearSvmParams, SmoParams};
use crate::tensorsketch::TensorSketch;
use crate::{Error, Result};

/// One measured pipeline variant.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub label: String,
    pub accuracy: f64,
    pub train_s: f64,
    pub test_s: f64,
    /// Support count (exact kernel) or feature count (random maps).
    pub size: usize,
}

/// All three variants on one dataset + kernel.
#[derive(Clone, Debug)]
pub struct RowResult {
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    pub kernel: String,
    pub exact: CellResult,
    pub rf: CellResult,
    pub h01: CellResult,
}

impl RowResult {
    /// Speedup strings like the paper's `(4.7×)` columns.
    pub fn speedup(&self, cell: &CellResult) -> (f64, f64) {
        (self.exact.train_s / cell.train_s.max(1e-9), self.exact.test_s / cell.test_s.max(1e-9))
    }
}

/// Prepared split + resolved kernel for an experiment.
pub struct Prepared {
    pub train: Dataset,
    pub test: Dataset,
    pub kernel: Box<dyn DotProductKernel>,
    pub config: ExperimentConfig,
}

/// Load the surrogate dataset, split and resolve the kernel width.
pub fn prepare(config: &ExperimentConfig) -> Result<Prepared> {
    let surrogate = UciSurrogate::from_name(&config.dataset)
        .ok_or_else(|| Error::Config(format!("unknown dataset {:?}", config.dataset)))?;
    let ds = surrogate.load(config.scale, config.seed);
    let mut rng = Rng::seed_from(config.seed ^ 0x5917);
    let (mut train, mut test) = ds.split(config.train_frac, config.max_train, &mut rng);
    if config.sparse {
        // Carry the splits in CSR so every transform below runs the
        // O(D·nnz) fast paths. Accuracies are unchanged by the sparse
        // parity contract; only the cost model moves.
        train = train.into_sparse();
        test = test.into_sparse();
    }
    // The paper's sigma heuristic: mean pairwise distance on train data.
    let sigma2_hint = if matches!(config.kernel, KernelSpec::Exponential { .. }) {
        let d = train.mean_pairwise_distance(2000.min(train.len() * 2), &mut rng);
        d * d
    } else {
        1.0
    };
    let kernel = config.kernel.build(sigma2_hint);
    Ok(Prepared { train, test, kernel, config: config.clone() })
}

/// Train + evaluate the exact kernel SVM (the `K + LIBSVM` column).
pub fn run_exact(prep: &Prepared, kernel: Box<dyn DotProductKernel>) -> CellResult {
    let sw = Stopwatch::start();
    let model = KernelSvm::train(
        &prep.train,
        kernel,
        SmoParams { c: prep.config.c, ..Default::default() },
    )
    .expect("SMO training failed");
    let train_s = sw.elapsed_secs();

    let sw = Stopwatch::start();
    let accuracy = model.accuracy_on(&prep.test);
    let test_s = sw.elapsed_secs();

    CellResult { label: "K+SMO".into(), accuracy, train_s, test_s, size: model.n_support() }
}

/// Train + evaluate random features + linear SVM (`RF`/`H0/1` columns).
/// Timings include feature-map construction and application, matching
/// the paper's protocol.
pub fn run_random_features(
    prep: &Prepared,
    n_features: usize,
    h01: bool,
    seed_offset: u64,
) -> CellResult {
    let mut rng = Rng::seed_from(prep.config.seed ^ 0xF00D ^ seed_offset);
    let rm_config = RmConfig::default()
        .with_p(prep.config.p)
        .with_h01(h01)
        .with_projection(prep.config.projection)
        .with_recycle(prep.config.recycle);

    let sw = Stopwatch::start();
    let map = RandomMaclaurin::sample(
        prep.kernel.as_ref(),
        prep.train.dim(),
        n_features,
        rm_config,
        &mut rng,
    );
    let label = if h01 { "H0/1+LIN" } else { "RF+LIN" };
    finish_linear(prep, &map, label.into(), sw)
}

/// Shared tail of every features-then-linear-SVM variant: transform the
/// train split, train the DCD linear SVM, transform + score the test
/// split. `sw` must have been started *before* the map was sampled, so
/// construction lands in `train_s` and per-example featurization in
/// `test_s` — the paper's timing protocol for the `+LIN` columns.
fn finish_linear(prep: &Prepared, map: &dyn FeatureMap, label: String, sw: Stopwatch) -> CellResult {
    let z_train = crate::features::transform_dataset(map, &prep.train);
    let z_ds = Dataset::new("z", z_train, prep.train.y.clone()).expect("uniform shapes");
    // LIBLINEAR's default iteration budget is larger than ours; give the
    // DCD solver enough epochs that the RF column is not convergence-
    // limited (the paper's comparison assumes both solvers converge).
    let model = LinearSvm::train(
        &z_ds,
        LinearSvmParams { c: prep.config.c, max_epochs: 500, ..Default::default() },
    )
    .expect("linear SVM training failed");
    let train_s = sw.elapsed_secs();

    let sw = Stopwatch::start();
    let z_test = crate::features::transform_dataset(map, &prep.test);
    let accuracy = model.accuracy(&z_test, &prep.test.y);
    let test_s = sw.elapsed_secs();

    CellResult { label, accuracy, train_s, test_s, size: map.output_dim() }
}

/// One grid-cell variant of the experiment: which learner / feature
/// map family to run on a prepared split. [`run_row`] is three of
/// these hard-wired into the paper's Table 1 shape; the report grid
/// ([`crate::report`]) drives the full family × kernel × D product
/// through [`run_variant`].
#[derive(Clone, Debug)]
pub enum MapVariant {
    /// Exact kernel SVM (SMO) — the `K + LIBSVM` column.
    Exact,
    /// Random Maclaurin features + linear SVM (Algorithm 1; with
    /// `h01`, the exact-low-order heuristic of §6.1).
    Maclaurin { d: usize, h01: bool },
    /// Random Fourier features + linear SVM. Applies to exponential
    /// kernels only: on L2-normalized data the Gaussian RBF at
    /// `γ = 1/(2σ²)` equals `e^{−2γ} · exp(⟨x, y⟩/σ²)`, so the RFF map
    /// targets the same decision surface up to a constant factor.
    Fourier { d: usize },
    /// TensorSketch + linear SVM (fixed-degree polynomial kernels only).
    TensorSketch { d: usize },
    /// Nyström landmark features + linear SVM (the data-dependent
    /// baseline; `m` landmarks = output dimension).
    Nystrom { m: usize },
}

impl MapVariant {
    /// Column label in the Table 1 style.
    pub fn label(&self) -> String {
        match self {
            MapVariant::Exact => "K+SMO".into(),
            MapVariant::Maclaurin { h01: false, .. } => "RF+LIN".into(),
            MapVariant::Maclaurin { h01: true, .. } => "H0/1+LIN".into(),
            MapVariant::Fourier { .. } => "RFF+LIN".into(),
            MapVariant::TensorSketch { .. } => "TS+LIN".into(),
            MapVariant::Nystrom { .. } => "NYS+LIN".into(),
        }
    }
}

/// Run one [`MapVariant`] on a prepared experiment: sample/fit the map
/// (timed), train, evaluate. This is [`run_row`] generalized beyond the
/// hard-wired exact/RF/H0/1 triple into arbitrary grid cells. `Err`
/// means the variant does not apply to the prepared kernel (H0/1 on a
/// kernel with no constant/linear term, RFF on a non-exponential
/// kernel, TensorSketch on a non-polynomial one) — callers render such
/// cells as explicitly skipped, never silently dropped.
pub fn run_variant(prep: &Prepared, variant: &MapVariant, seed_offset: u64) -> Result<CellResult> {
    match variant {
        MapVariant::Exact => {
            Ok(run_exact(prep, prep.config.kernel.build(kernel_sigma2(prep))))
        }
        MapVariant::Maclaurin { d, h01 } => {
            if *h01 && prep.kernel.coeff(0) <= 0.0 && prep.kernel.coeff(1) <= 0.0 {
                return Err(Error::Config(
                    "H0/1 needs a_0 > 0 or a_1 > 0 (homogeneous kernels have neither)".into(),
                ));
            }
            Ok(run_random_features(prep, *d, *h01, seed_offset))
        }
        MapVariant::Fourier { d } => {
            if !matches!(prep.config.kernel, KernelSpec::Exponential { .. }) {
                return Err(Error::Config(
                    "random Fourier features apply to exponential kernels only \
                     (RBF on the unit sphere)"
                        .into(),
                ));
            }
            let sigma2 = kernel_sigma2(prep);
            let mut rng = Rng::seed_from(prep.config.seed ^ 0xF0F0 ^ seed_offset);
            let sw = Stopwatch::start();
            let map = RandomFourier::sample_with_opts(
                0.5 / sigma2,
                prep.train.dim(),
                *d,
                prep.config.projection,
                prep.config.recycle,
                &mut rng,
            );
            Ok(finish_linear(prep, &map, variant.label(), sw))
        }
        MapVariant::TensorSketch { d } => {
            let (degree, offset) = match prep.config.kernel {
                KernelSpec::Polynomial { degree, offset } => (degree, offset),
                KernelSpec::Homogeneous { degree } => (degree, 0.0),
                _ => {
                    return Err(Error::Config(
                        "tensorsketch sketches fixed-degree polynomial kernels only".into(),
                    ))
                }
            };
            let mut rng = Rng::seed_from(prep.config.seed ^ 0x75C7 ^ seed_offset);
            let sw = Stopwatch::start();
            let map = TensorSketch::sample(degree, offset, prep.train.dim(), *d, &mut rng);
            Ok(finish_linear(prep, &map, variant.label(), sw))
        }
        MapVariant::Nystrom { m } => {
            let mut rng = Rng::seed_from(prep.config.seed ^ 0x9A57 ^ seed_offset);
            let sw = Stopwatch::start();
            let map = Nystrom::fit(
                prep.config.kernel.build(kernel_sigma2(prep)),
                prep.train.x(),
                *m,
                &mut rng,
            )?;
            Ok(finish_linear(prep, &map, variant.label(), sw))
        }
    }
}

/// Run a full Table 1 row: exact kernel vs RF(D=`d_rf`) vs
/// H0/1(D=`d_h01`). For kernels with no constant/linear terms
/// (homogeneous), the H0/1 cell reuses plain RF at `d_h01` (the paper
/// notes H0/1 does not apply there).
pub fn run_row(config: &ExperimentConfig, d_rf: usize, d_h01: usize) -> Result<RowResult> {
    // The experiment's parallelism knob: 0 leaves the global budget
    // (auto-detected or RFDOT_THREADS) untouched.
    if config.threads > 0 {
        crate::parallel::set_max_threads(config.threads);
    }
    // Same contract for the kernel-dispatch knob: None leaves the
    // process-global mode (auto-detect or RFDOT_SIMD) untouched.
    if let Some(mode) = config.simd {
        crate::simd::set_mode(mode);
    }
    // And for the tracing knob: None leaves the process-global enable
    // flag (--trace / RFDOT_TRACE) untouched.
    if let Some(on) = config.trace {
        crate::obs::set_enabled(on);
    }
    let prep = prepare(config)?;
    let exact = run_exact(&prep, prep.config.kernel.build(kernel_sigma2(&prep)));
    let rf = run_random_features(&prep, d_rf, false, 1);
    let h01_applies =
        prep.kernel.coeff(0) > 0.0 || prep.kernel.coeff(1) > 0.0;
    let h01 = run_random_features(&prep, d_h01, h01_applies, 2);
    Ok(RowResult {
        dataset: prep.train.name.clone(),
        n_train: prep.train.len(),
        n_test: prep.test.len(),
        d: prep.train.dim(),
        kernel: prep.kernel.name(),
        exact,
        rf,
        h01,
    })
}

fn kernel_sigma2(prep: &Prepared) -> f64 {
    // Re-extract the resolved width so `run_exact` builds the identical
    // kernel object (build() is cheap; hint only matters for Exponential).
    if let KernelSpec::Exponential { .. } = prep.config.kernel {
        if let Some(rest) = prep.kernel.name().strip_prefix("exponential(sigma2=") {
            if let Some(num) = rest.strip_suffix(")") {
                return num.parse().unwrap_or(1.0);
            }
        }
    }
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> ExperimentConfig {
        ExperimentConfig {
            dataset: "nursery".into(),
            scale: 0.03, // ~390 examples
            kernel: KernelSpec::Polynomial { degree: 10, offset: 1.0 },
            n_features: 128,
            c: 1.0,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn prepare_splits_and_resolves_kernel() {
        let prep = prepare(&tiny_config()).unwrap();
        assert!(prep.train.len() > 100);
        assert!(prep.test.len() > 50);
        assert_eq!(prep.train.dim(), 8);
        assert!(prep.kernel.name().contains("polynomial"));
    }

    #[test]
    fn exponential_sigma_resolved_from_data() {
        let cfg = ExperimentConfig {
            kernel: KernelSpec::Exponential { sigma2: 0.0 },
            ..tiny_config()
        };
        let prep = prepare(&cfg).unwrap();
        // Normalized rows: mean pairwise distance in (0, 2); sigma2 in (0, 4].
        let name = prep.kernel.name();
        assert!(name.contains("exponential"), "{name}");
        let v: f64 = name
            .trim_start_matches("exponential(sigma2=")
            .trim_end_matches(')')
            .parse()
            .unwrap();
        assert!(v > 0.0 && v <= 4.0, "sigma2 {v}");
    }

    #[test]
    fn full_row_shapes_hold() {
        // The core Table 1 claim, in miniature: RF accuracy within a few
        // points of exact, both well above chance, large test speedup.
        let row = run_row(&tiny_config(), 256, 64).unwrap();
        assert!(row.exact.accuracy > 0.8, "exact acc {}", row.exact.accuracy);
        assert!(row.rf.accuracy > 0.75, "rf acc {}", row.rf.accuracy);
        assert!(row.h01.accuracy > 0.75, "h01 acc {}", row.h01.accuracy);
        assert!(row.exact.size > 0);
        assert_eq!(row.rf.size, 256);
        assert_eq!(row.h01.size, 1 + 8 + 64);
    }

    #[test]
    fn run_variant_generalizes_the_table1_columns() {
        // The generalized cell runner must (a) reproduce the legacy RF
        // column bit for bit, (b) run the post-paper families, and (c)
        // reject inapplicable (variant, kernel) pairs with an error the
        // report grid can surface as an explicit skip.
        let prep = prepare(&tiny_config()).unwrap();
        let legacy = run_random_features(&prep, 64, false, 1);
        let via_variant =
            run_variant(&prep, &MapVariant::Maclaurin { d: 64, h01: false }, 1).unwrap();
        assert_eq!(legacy.accuracy, via_variant.accuracy);
        assert_eq!(legacy.size, via_variant.size);

        // TensorSketch accuracy is asserted on a low degree (a degree-10
        // sketch at width 64 is legitimately high-variance); on the
        // degree-10 prep just check it runs and reports its width.
        let ts = run_variant(&prep, &MapVariant::TensorSketch { d: 64 }, 2).unwrap();
        assert_eq!(ts.label, "TS+LIN");
        assert_eq!(ts.size, 64);
        let p3 = ExperimentConfig {
            kernel: KernelSpec::Polynomial { degree: 3, offset: 1.0 },
            ..tiny_config()
        };
        let p3_prep = prepare(&p3).unwrap();
        let ts3 = run_variant(&p3_prep, &MapVariant::TensorSketch { d: 128 }, 2).unwrap();
        assert!(ts3.accuracy > 0.6, "ts acc {}", ts3.accuracy);
        let ny = run_variant(&prep, &MapVariant::Nystrom { m: 32 }, 3).unwrap();
        assert_eq!(ny.size, 32);
        assert!(ny.accuracy > 0.6, "nystrom acc {}", ny.accuracy);

        // Polynomial kernel: RFF does not apply.
        assert!(run_variant(&prep, &MapVariant::Fourier { d: 32 }, 4).is_err());
        // Homogeneous kernel: H0/1 does not apply, TS does.
        let hom = ExperimentConfig {
            kernel: KernelSpec::Homogeneous { degree: 3 },
            ..tiny_config()
        };
        let hom_prep = prepare(&hom).unwrap();
        assert!(
            run_variant(&hom_prep, &MapVariant::Maclaurin { d: 32, h01: true }, 5).is_err()
        );
        assert!(run_variant(&hom_prep, &MapVariant::TensorSketch { d: 32 }, 6).is_ok());
        // Exponential kernel: RFF applies.
        let exp = ExperimentConfig {
            kernel: KernelSpec::Exponential { sigma2: 1.0 },
            ..tiny_config()
        };
        let exp_prep = prepare(&exp).unwrap();
        let rff = run_variant(&exp_prep, &MapVariant::Fourier { d: 64 }, 7).unwrap();
        assert_eq!(rff.label, "RFF+LIN");
        assert!(rff.accuracy > 0.6, "rff acc {}", rff.accuracy);
    }

    #[test]
    fn unknown_dataset_is_an_error() {
        let cfg = ExperimentConfig { dataset: "mystery".into(), ..tiny_config() };
        assert!(prepare(&cfg).is_err());
    }

    #[test]
    fn sparse_row_equals_dense_row_exactly() {
        // The sparse parity contract, end to end through Table 1: CSR
        // splits feed the O(D·nnz) paths, yet every accuracy must equal
        // the dense pipeline's bit for bit (same transforms, same SVMs).
        let dense_cfg = tiny_config();
        let sparse_cfg = ExperimentConfig { sparse: true, ..tiny_config() };
        let dense_row = run_row(&dense_cfg, 128, 32).unwrap();
        let sparse_row = run_row(&sparse_cfg, 128, 32).unwrap();
        assert_eq!(dense_row.exact.accuracy, sparse_row.exact.accuracy);
        assert_eq!(dense_row.rf.accuracy, sparse_row.rf.accuracy);
        assert_eq!(dense_row.h01.accuracy, sparse_row.h01.accuracy);
    }

    #[test]
    fn structured_row_stays_in_the_dense_accuracy_envelope() {
        // The Table-1 claim must survive the projection swap: random
        // features through FWHT blocks learn as well as dense ones.
        let cfg = ExperimentConfig {
            projection: crate::structured::ProjectionKind::Structured,
            ..tiny_config()
        };
        let row = run_row(&cfg, 256, 64).unwrap();
        assert!(row.rf.accuracy > 0.75, "structured rf acc {}", row.rf.accuracy);
        assert!(row.h01.accuracy > 0.75, "structured h01 acc {}", row.h01.accuracy);
    }
}
