//! Minimal JSON parser (no external crates are reachable offline).
//!
//! Supports the full JSON grammar except exotic number forms beyond
//! `f64` precision. Used for the AOT artifact manifests emitted by
//! `python/compile/aot.py` and for experiment config files.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `get(key)` that errors with context when missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing field {key:?}")))
    }

    /// Render with two-space indentation and a trailing newline. Object
    /// fields come out in `BTreeMap` order and numbers use the same
    /// shortest-roundtrip formatting as [`Json::to_string`], so equal
    /// values always produce byte-identical documents — the property
    /// the report subsystem's regeneration contract rests on.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    out.push_str(&pad);
                    v.pretty_into(out, indent + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad);
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, indent + 1);
                    out.push_str(if i + 1 < map.len() { ",\n" } else { "\n" });
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            scalar_or_empty => out.push_str(&scalar_or_empty.to_string()),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "name": "transform_quickstart",
          "config": {"kind": "transform", "batch": 128, "d": 16,
                     "n_max": 8, "features": 256},
          "inputs": [{"name": "x", "shape": [128, 16], "dtype": "f32"}],
          "format": "hlo-text/return-tuple"
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req("config").unwrap().req("batch").unwrap().as_usize(), Some(128));
        let shape = v.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "\"unterminated", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn pretty_roundtrips_and_is_deterministic() {
        let src = r#"{"b": [1, 2.5, {"x": true}], "a": "s", "empty": [], "o": {}}"#;
        let v = Json::parse(src).unwrap();
        let p = v.pretty();
        // Parses back to the same value...
        assert_eq!(Json::parse(&p).unwrap(), v);
        // ...is stable under re-rendering (byte-identical regeneration)...
        assert_eq!(Json::parse(&p).unwrap().pretty(), p);
        // ...and is actually indented, with sorted keys and compact
        // empty containers.
        assert!(p.starts_with("{\n  \"a\": \"s\",\n  \"b\": [\n"), "{p}");
        assert!(p.contains("\"empty\": []"));
        assert!(p.contains("\"o\": {}"));
        assert!(p.ends_with("}\n"));
    }

    #[test]
    fn display_roundtrips() {
        let src = r#"{"a":[1,2.5,true,null],"b":{"c":"d\"e"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
