//! Configuration system.
//!
//! [`json`] is the low-level parser (also used for artifact manifests);
//! [`ExperimentConfig`] / [`ServeConfig`] are the typed configs the CLI
//! and bench harness consume, loadable from JSON files with environment
//! overrides (`RFDOT_*`).

pub mod json;

use crate::structured::ProjectionKind;
use crate::{Error, Result};
use json::Json;
use std::path::Path;

/// Which kernel to build a feature map for.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelSpec {
    /// `⟨x, y⟩^degree`
    Homogeneous { degree: u32 },
    /// `(⟨x, y⟩ + offset)^degree`
    Polynomial { degree: u32, offset: f64 },
    /// `exp(⟨x, y⟩ / sigma2)`; `sigma2 = 0` means "fit from data" via
    /// the paper's mean-pairwise-distance heuristic.
    Exponential { sigma2: f64 },
    /// Vovk's real polynomial kernel.
    VovkReal { degree: u32 },
    /// Scaled Vovk infinite kernel `1 / (1 − t/c)`.
    VovkInfinite { scale: f64 },
}

impl KernelSpec {
    /// Instantiate the kernel object (`sigma2_hint` resolves the
    /// data-dependent exponential width).
    pub fn build(&self, sigma2_hint: f64) -> Box<dyn crate::kernels::DotProductKernel> {
        match *self {
            KernelSpec::Homogeneous { degree } => {
                Box::new(crate::kernels::Homogeneous::new(degree))
            }
            KernelSpec::Polynomial { degree, offset } => {
                Box::new(crate::kernels::Polynomial::new(degree, offset))
            }
            KernelSpec::Exponential { sigma2 } => Box::new(crate::kernels::Exponential::new(
                if sigma2 > 0.0 { sigma2 } else { sigma2_hint.max(1e-6) },
            )),
            KernelSpec::VovkReal { degree } => Box::new(crate::kernels::VovkReal::new(degree)),
            KernelSpec::VovkInfinite { scale } => {
                Box::new(crate::kernels::Scaled::new(crate::kernels::VovkInfinite, scale))
            }
        }
    }

    /// Parse from CLI-style strings like `poly:10:1.0`, `exp`, `hom:10`,
    /// `vovk-real:6`, `vovk-inf:4`.
    pub fn parse(s: &str) -> Result<KernelSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize, default: f64| -> Result<f64> {
            parts
                .get(i)
                .map(|t| {
                    t.parse::<f64>()
                        .map_err(|_| Error::Config(format!("bad number {t:?} in kernel {s:?}")))
                })
                .unwrap_or(Ok(default))
        };
        Ok(match parts[0] {
            "poly" | "polynomial" => KernelSpec::Polynomial {
                degree: num(1, 10.0)? as u32,
                offset: num(2, 1.0)?,
            },
            "hom" | "homogeneous" => KernelSpec::Homogeneous { degree: num(1, 10.0)? as u32 },
            "exp" | "exponential" => KernelSpec::Exponential { sigma2: num(1, 0.0)? },
            "vovk-real" => KernelSpec::VovkReal { degree: num(1, 6.0)? as u32 },
            "vovk-inf" | "vovk-infinite" => KernelSpec::VovkInfinite { scale: num(1, 4.0)? },
            other => return Err(Error::Config(format!("unknown kernel {other:?}"))),
        })
    }

    fn from_json(v: &Json) -> Result<KernelSpec> {
        let kind = v.req("kind")?.as_str().unwrap_or_default();
        let f = |k: &str, d: f64| v.get(k).and_then(Json::as_f64).unwrap_or(d);
        Ok(match kind {
            "homogeneous" => KernelSpec::Homogeneous { degree: f("degree", 10.0) as u32 },
            "polynomial" => KernelSpec::Polynomial {
                degree: f("degree", 10.0) as u32,
                offset: f("offset", 1.0),
            },
            "exponential" => KernelSpec::Exponential { sigma2: f("sigma2", 0.0) },
            "vovk-real" => KernelSpec::VovkReal { degree: f("degree", 6.0) as u32 },
            "vovk-infinite" => KernelSpec::VovkInfinite { scale: f("scale", 4.0) },
            other => return Err(Error::Config(format!("unknown kernel kind {other:?}"))),
        })
    }
}

/// A full train/eval experiment description (one Table 1 cell group).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset name (UCI surrogate) — see `data::UciSurrogate`.
    pub dataset: String,
    /// Size scale relative to the paper's N.
    pub scale: f64,
    pub kernel: KernelSpec,
    /// Number of random features D.
    pub n_features: usize,
    /// Use H0/1.
    pub h01: bool,
    /// External measure parameter p.
    pub p: f64,
    /// SVM C.
    pub c: f64,
    /// RNG seed.
    pub seed: u64,
    /// Train fraction and cap (paper: 0.6 / 20000).
    pub train_frac: f64,
    pub max_train: usize,
    /// Data-parallel worker threads for the hot paths (feature
    /// transforms, GEMM, Gram matrices); `0` = leave the global
    /// [`crate::parallel`] knob untouched (auto / `RFDOT_THREADS`).
    pub threads: usize,
    /// Projection realization for the sampled feature maps: dense
    /// stacks or the FWHT-backed [`crate::structured`] HD blocks
    /// (JSON: `"projection": "dense" | "structured"`).
    pub projection: ProjectionKind,
    /// Carry the train/test splits in CSR storage and route transforms
    /// through the `O(D·nnz)` sparse fast paths (JSON: `"sparse"`).
    /// Results are unchanged by the crate's sparse parity contract;
    /// only the cost model moves.
    pub sparse: bool,
    /// Recycle randomness across structured HD/Fastfood blocks (JSON:
    /// `"recycle"`): blocks draw their Rademacher/Gaussian state from
    /// one shared pool in the map artifact instead of independent
    /// per-block samples, shrinking serialized state. Default off so
    /// the default numerics stay bit-identical; no effect on dense
    /// projections.
    pub recycle: bool,
    /// Kernel-dispatch override for the [`crate::simd`] layer (JSON:
    /// `"simd": "scalar" | "auto"`); `None` leaves the process-global
    /// knob untouched (auto-detect or `RFDOT_SIMD`).
    pub simd: Option<crate::simd::SimdMode>,
    /// Tracing-span override for the [`crate::obs`] layer (JSON:
    /// `"trace": true | false`); `None` leaves the process-global
    /// enable flag untouched (`--trace` / `RFDOT_TRACE`). Like `simd`,
    /// the knob is only *applied* by consumers — parsing never mutates
    /// the global.
    pub trace: Option<bool>,
    /// Fault-injection spec for the [`crate::faults`] layer (JSON:
    /// `"faults": "seed=7,net.write=error:0.1"`); `None` leaves the
    /// process-global plan untouched (`--faults` / `RFDOT_FAULTS`).
    /// Parsed and *validated* here, applied only by consumers.
    pub faults: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "nursery".into(),
            scale: 0.1,
            kernel: KernelSpec::Polynomial { degree: 10, offset: 1.0 },
            n_features: 500,
            h01: false,
            p: 2.0,
            c: 1.0,
            seed: 42,
            train_frac: 0.6,
            max_train: 20_000,
            threads: 0,
            projection: ProjectionKind::Dense,
            sparse: false,
            recycle: false,
            simd: None,
            trace: None,
            faults: None,
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON document, starting from defaults.
    pub fn from_json(text: &str) -> Result<ExperimentConfig> {
        let v = Json::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = v.get("dataset").and_then(Json::as_str) {
            cfg.dataset = s.to_string();
        }
        if let Some(n) = v.get("scale").and_then(Json::as_f64) {
            cfg.scale = n;
        }
        if let Some(k) = v.get("kernel") {
            cfg.kernel = KernelSpec::from_json(k)?;
        }
        if let Some(n) = v.get("n_features").and_then(Json::as_usize) {
            cfg.n_features = n;
        }
        if let Some(b) = v.get("h01").and_then(Json::as_bool) {
            cfg.h01 = b;
        }
        if let Some(n) = v.get("p").and_then(Json::as_f64) {
            cfg.p = n;
        }
        if let Some(n) = v.get("c").and_then(Json::as_f64) {
            cfg.c = n;
        }
        if let Some(n) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = n as u64;
        }
        if let Some(n) = v.get("train_frac").and_then(Json::as_f64) {
            cfg.train_frac = n;
        }
        if let Some(n) = v.get("max_train").and_then(Json::as_usize) {
            cfg.max_train = n;
        }
        if let Some(n) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads = n;
        }
        if let Some(s) = v.get("projection").and_then(Json::as_str) {
            cfg.projection = ProjectionKind::parse(s)?;
        }
        if let Some(b) = v.get("sparse").and_then(Json::as_bool) {
            cfg.sparse = b;
        }
        if let Some(b) = v.get("recycle").and_then(Json::as_bool) {
            cfg.recycle = b;
        }
        if let Some(s) = v.get("simd").and_then(Json::as_str) {
            cfg.simd = Some(crate::simd::SimdMode::parse(s)?);
        }
        if let Some(b) = v.get("trace").and_then(Json::as_bool) {
            cfg.trace = Some(b);
        }
        if let Some(s) = v.get("faults").and_then(Json::as_str) {
            // Validate eagerly so a typo'd site name fails at config
            // parse time, but install nothing — like simd/trace, the
            // global is only mutated by consumers.
            crate::faults::parse_spec(s)?;
            cfg.faults = Some(s.to_string());
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.n_features == 0 {
            return Err(Error::Config("n_features must be positive".into()));
        }
        if !(self.p > 1.0) {
            return Err(Error::Config(format!("p must be > 1, got {}", self.p)));
        }
        if !(self.c > 0.0) {
            return Err(Error::Config("C must be positive".into()));
        }
        if !(0.0 < self.train_frac && self.train_frac < 1.0) {
            return Err(Error::Config("train_frac must be in (0, 1)".into()));
        }
        if !(self.scale > 0.0) {
            return Err(Error::Config("scale must be positive".into()));
        }
        Ok(())
    }
}

/// Configuration of the `rfdot report` reproduction grid — the
/// `"report"` section of a JSON config file (see
/// [`crate::report`]). Two baselines exist: [`ReportConfig::quick`]
/// (the CI-sized slice `report --quick` runs) and
/// [`ReportConfig::full`] (the paper-scale grid); a config file starts
/// from one of them (`"quick": true|false`) and overrides fields.
#[derive(Clone, Debug)]
pub struct ReportConfig {
    /// CI-sized slice (small grid, few runs) instead of the full grid.
    pub quick: bool,
    /// Master seed; every grid cell derives its own RNG stream from it
    /// (order-independent, so resumed and fresh runs agree bit for bit
    /// on every seed-deterministic quantity).
    pub seed: u64,
    /// Directory receiving `REPORT.md`, `REPORT.json`, the `report/`
    /// SVG assets and the resumable run-log.
    pub out_dir: String,
    /// Reuse completed cells from an existing run-log (resume); `false`
    /// (`--fresh`) re-measures everything.
    pub resume: bool,
    /// Input dimensionality of the synthetic gram-error point set.
    pub dim: usize,
    /// Number of points in the gram-error set.
    pub points: usize,
    /// Independent map resamples per cell (the error envelope width).
    pub runs: usize,
    /// The D sweep (target output dimensions), ascending.
    pub d_sweep: Vec<usize>,
    /// Kernels in CLI spelling (`poly:10:1`, `hom:4`, `exp:1`, ...).
    pub kernels: Vec<String>,
    /// Thread counts for the transform scaling sweep.
    pub threads_sweep: Vec<usize>,
    /// Datasets for the Table-1-style accuracy rows.
    pub datasets: Vec<String>,
    /// Dataset size scale for the accuracy rows.
    pub scale: f64,
    /// Random-feature count D for the accuracy rows.
    pub accuracy_features: usize,
    /// Requests per serving-panel point (the coordinator throughput
    /// sweep over worker count × shared-vs-sharded queue topology).
    pub serve_requests: usize,
}

impl ReportConfig {
    /// The CI-sized slice: seconds, not minutes, but still touching
    /// every family × kernel × projection × storage combination.
    pub fn quick() -> ReportConfig {
        ReportConfig {
            quick: true,
            seed: 42,
            out_dir: ".".into(),
            resume: true,
            dim: 8,
            points: 20,
            runs: 2,
            d_sweep: vec![16, 32],
            kernels: vec!["poly:3:1".into(), "exp:1".into()],
            threads_sweep: vec![1, 2],
            datasets: vec!["nursery".into()],
            scale: 0.02,
            accuracy_features: 64,
            serve_requests: 200,
        }
    }

    /// The paper-scale grid (minutes; interruptible and resumable via
    /// the run-log).
    pub fn full() -> ReportConfig {
        ReportConfig {
            quick: false,
            seed: 42,
            out_dir: ".".into(),
            resume: true,
            dim: 16,
            points: 100,
            runs: 5,
            d_sweep: vec![64, 128, 256, 512, 1024],
            kernels: vec!["poly:10:1".into(), "hom:4".into(), "exp:1".into()],
            threads_sweep: vec![1, 2, 4, 8],
            datasets: vec!["nursery".into(), "spambase".into()],
            scale: 0.1,
            accuracy_features: 500,
            serve_requests: 2000,
        }
    }

    /// Parse the `"report"` section of a JSON document (or a document
    /// that *is* the section), starting from the [`ReportConfig::quick`]
    /// or [`ReportConfig::full`] baseline chosen by its `"quick"` field
    /// (default full).
    pub fn from_json(text: &str) -> Result<ReportConfig> {
        let doc = Json::parse(text)?;
        let v = doc.get("report").unwrap_or(&doc);
        let quick = v.get("quick").and_then(Json::as_bool).unwrap_or(false);
        let mut cfg = if quick { ReportConfig::quick() } else { ReportConfig::full() };
        if let Some(n) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = n as u64;
        }
        if let Some(s) = v.get("out_dir").and_then(Json::as_str) {
            cfg.out_dir = s.to_string();
        }
        if let Some(b) = v.get("resume").and_then(Json::as_bool) {
            cfg.resume = b;
        }
        if let Some(n) = v.get("dim").and_then(Json::as_usize) {
            cfg.dim = n;
        }
        if let Some(n) = v.get("points").and_then(Json::as_usize) {
            cfg.points = n;
        }
        if let Some(n) = v.get("runs").and_then(Json::as_usize) {
            cfg.runs = n;
        }
        if let Some(a) = v.get("d_sweep").and_then(Json::as_arr) {
            cfg.d_sweep = usize_list(a, "d_sweep")?;
        }
        if let Some(a) = v.get("kernels").and_then(Json::as_arr) {
            cfg.kernels = str_list(a, "kernels")?;
        }
        if let Some(a) = v.get("threads_sweep").and_then(Json::as_arr) {
            cfg.threads_sweep = usize_list(a, "threads_sweep")?;
        }
        if let Some(a) = v.get("datasets").and_then(Json::as_arr) {
            cfg.datasets = str_list(a, "datasets")?;
        }
        if let Some(n) = v.get("scale").and_then(Json::as_f64) {
            cfg.scale = n;
        }
        if let Some(n) = v.get("accuracy_features").and_then(Json::as_usize) {
            cfg.accuracy_features = n;
        }
        if let Some(n) = v.get("serve_requests").and_then(Json::as_usize) {
            cfg.serve_requests = n;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<ReportConfig> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    /// Sanity-check field ranges (every kernel spelling must parse).
    pub fn validate(&self) -> Result<()> {
        if self.dim == 0 || self.points < 2 {
            return Err(Error::Config("report needs dim > 0 and points >= 2".into()));
        }
        if self.runs == 0 {
            return Err(Error::Config("report runs must be positive".into()));
        }
        if self.d_sweep.is_empty() || self.d_sweep.contains(&0) {
            return Err(Error::Config("d_sweep must be non-empty and positive".into()));
        }
        if self.kernels.is_empty() || self.threads_sweep.is_empty() || self.datasets.is_empty() {
            return Err(Error::Config(
                "kernels, threads_sweep and datasets must be non-empty".into(),
            ));
        }
        if self.threads_sweep.contains(&0) {
            return Err(Error::Config("threads_sweep entries must be positive".into()));
        }
        for k in &self.kernels {
            KernelSpec::parse(k)?;
        }
        if !(self.scale > 0.0) {
            return Err(Error::Config("report scale must be positive".into()));
        }
        if self.accuracy_features == 0 {
            return Err(Error::Config("accuracy_features must be positive".into()));
        }
        if self.serve_requests == 0 {
            return Err(Error::Config("serve_requests must be positive".into()));
        }
        Ok(())
    }

    /// Stable fingerprint of everything that changes cell *results*
    /// (mode, seed and grid axes — not `out_dir`/`resume`). The run-log
    /// stores it and refuses to resume across a mismatch, so a stale
    /// log can never leak cells into a differently-shaped report.
    pub fn fingerprint(&self) -> String {
        format!(
            "report-v2:quick={}:seed={}:dim={}:points={}:runs={}:d={:?}:kernels={:?}:\
             threads={:?}:datasets={:?}:scale={}:accuracy_features={}:serve_requests={}",
            self.quick,
            self.seed,
            self.dim,
            self.points,
            self.runs,
            self.d_sweep,
            self.kernels,
            self.threads_sweep,
            self.datasets,
            self.scale,
            self.accuracy_features,
            self.serve_requests,
        )
    }
}

/// Decode a JSON array of non-negative integers (shared with the
/// report schema decoder in [`crate::report`]).
pub(crate) fn usize_list(a: &[Json], field: &str) -> Result<Vec<usize>> {
    a.iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| Error::Config(format!("{field} entries must be non-negative ints")))
        })
        .collect()
}

/// Decode a JSON array of strings (shared with the report schema
/// decoder in [`crate::report`]).
pub(crate) fn str_list(a: &[Json], field: &str) -> Result<Vec<String>> {
    a.iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::Config(format!("{field} entries must be strings")))
        })
        .collect()
}

/// Serving configuration (`rfdot serve` / examples/serve_features.rs).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifact name to load (kind `transform` or `transform_score`).
    pub artifact: String,
    pub artifact_dir: String,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    pub queue_depth: usize,
    pub workers: usize,
    /// Batch-queue shards (`0` = one per worker, the work-stealing
    /// default; `1` = the shared-queue baseline topology).
    pub shards: usize,
    /// Fall back to the native engine instead of PJRT.
    pub native: bool,
    pub seed: u64,
    /// TCP bind address for the network front-end (empty = the
    /// in-process serving demo; see `rfdot serve --listen`).
    pub listen: String,
    /// Heartbeat interval in milliseconds: the connection read timeout
    /// and the liveness accounting unit.
    pub heartbeat_ms: u64,
    /// Consecutive silent heartbeat intervals before a client is reaped.
    pub max_missed: u32,
    /// Bounded per-client write-back queue (reply permits); overflow
    /// surfaces as a retryable reject frame, never an unbounded buffer.
    pub write_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact: "transform_serve".into(),
            artifact_dir: "artifacts".into(),
            max_batch: 256,
            max_wait_ms: 2,
            queue_depth: 4096,
            workers: 2,
            shards: 0,
            native: false,
            seed: 7,
            listen: String::new(),
            heartbeat_ms: 2000,
            max_missed: 3,
            write_queue: 256,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_net_defaults_match_net_config() {
        let s = ServeConfig::default();
        let n = crate::net::NetConfig::default();
        assert_eq!(std::time::Duration::from_millis(s.heartbeat_ms), n.heartbeat);
        assert_eq!(s.max_missed, n.max_missed);
        assert_eq!(s.write_queue, n.write_queue);
        assert!(s.listen.is_empty(), "default stays the in-process serving demo");
    }

    #[test]
    fn kernel_spec_cli_parse() {
        assert_eq!(
            KernelSpec::parse("poly:10:1").unwrap(),
            KernelSpec::Polynomial { degree: 10, offset: 1.0 }
        );
        assert_eq!(KernelSpec::parse("hom:4").unwrap(), KernelSpec::Homogeneous { degree: 4 });
        assert_eq!(KernelSpec::parse("exp").unwrap(), KernelSpec::Exponential { sigma2: 0.0 });
        assert!(KernelSpec::parse("nope").is_err());
        assert!(KernelSpec::parse("poly:x").is_err());
    }

    #[test]
    fn kernel_spec_builds() {
        let k = KernelSpec::parse("exp").unwrap().build(0.5);
        assert!(k.name().contains("0.5"));
        let k2 = KernelSpec::parse("exp:2.0").unwrap().build(0.5);
        assert!(k2.name().contains("2"));
    }

    #[test]
    fn experiment_config_from_json() {
        let cfg = ExperimentConfig::from_json(
            r#"{"dataset": "spambase", "n_features": 100,
                "kernel": {"kind": "exponential"}, "h01": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "spambase");
        assert_eq!(cfg.n_features, 100);
        assert!(cfg.h01);
        assert_eq!(cfg.kernel, KernelSpec::Exponential { sigma2: 0.0 });
        // Defaults survive.
        assert_eq!(cfg.max_train, 20_000);
        assert_eq!(cfg.threads, 0);
        assert_eq!(cfg.projection, ProjectionKind::Dense);
        let with_threads =
            ExperimentConfig::from_json(r#"{"threads": 4}"#).unwrap();
        assert_eq!(with_threads.threads, 4);
        let structured =
            ExperimentConfig::from_json(r#"{"projection": "structured"}"#).unwrap();
        assert_eq!(structured.projection, ProjectionKind::Structured);
        assert!(ExperimentConfig::from_json(r#"{"projection": "sparse"}"#).is_err());
        assert!(!cfg.sparse);
        let sparse = ExperimentConfig::from_json(r#"{"sparse": true}"#).unwrap();
        assert!(sparse.sparse);
        assert!(!cfg.recycle, "recycling must default off (bit-identical numerics)");
        let recycled = ExperimentConfig::from_json(r#"{"recycle": true}"#).unwrap();
        assert!(recycled.recycle);
        // The simd knob parses but is only *applied* by consumers
        // (run_row), so decoding here never mutates the global mode.
        assert_eq!(cfg.simd, None);
        let forced = ExperimentConfig::from_json(r#"{"simd": "scalar"}"#).unwrap();
        assert_eq!(forced.simd, Some(crate::simd::SimdMode::Scalar));
        assert!(ExperimentConfig::from_json(r#"{"simd": "avx512"}"#).is_err());
        // Same contract for the trace knob: parsed, never applied here.
        assert_eq!(cfg.trace, None);
        let traced = ExperimentConfig::from_json(r#"{"trace": true}"#).unwrap();
        assert_eq!(traced.trace, Some(true));
        let untraced = ExperimentConfig::from_json(r#"{"trace": false}"#).unwrap();
        assert_eq!(untraced.trace, Some(false));
        // And for the faults knob: parsed + validated, never installed.
        assert_eq!(cfg.faults, None);
        let faulted =
            ExperimentConfig::from_json(r#"{"faults": "seed=7,net.write=error:0.1"}"#).unwrap();
        assert_eq!(faulted.faults.as_deref(), Some("seed=7,net.write=error:0.1"));
        assert!(ExperimentConfig::from_json(r#"{"faults": "net.typo=error"}"#).is_err());
    }

    #[test]
    fn report_config_from_json_overrides_baseline() {
        let cfg = ReportConfig::from_json(
            r#"{"report": {"quick": true, "seed": 7, "d_sweep": [8, 16],
                "kernels": ["poly:2:1"], "datasets": ["spambase"]}}"#,
        )
        .unwrap();
        assert!(cfg.quick);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.d_sweep, vec![8, 16]);
        assert_eq!(cfg.kernels, vec!["poly:2:1".to_string()]);
        assert_eq!(cfg.datasets, vec!["spambase".to_string()]);
        // Unset fields keep the quick baseline.
        assert_eq!(cfg.runs, ReportConfig::quick().runs);
        // A bare section (no "report" wrapper) parses too.
        let flat = ReportConfig::from_json(r#"{"points": 50}"#).unwrap();
        assert!(!flat.quick);
        assert_eq!(flat.points, 50);
    }

    #[test]
    fn report_config_serving_panel_knob() {
        assert_eq!(ReportConfig::quick().serve_requests, 200);
        assert_eq!(ReportConfig::full().serve_requests, 2000);
        let cfg =
            ReportConfig::from_json(r#"{"report": {"quick": true, "serve_requests": 64}}"#)
                .unwrap();
        assert_eq!(cfg.serve_requests, 64);
        assert!(ReportConfig::from_json(r#"{"serve_requests": 0}"#).is_err());
        // The knob changes results, so it is part of the fingerprint.
        let mut other = ReportConfig::quick();
        other.serve_requests += 1;
        assert_ne!(ReportConfig::quick().fingerprint(), other.fingerprint());
    }

    #[test]
    fn report_config_validates() {
        assert!(ReportConfig::from_json(r#"{"d_sweep": []}"#).is_err());
        assert!(ReportConfig::from_json(r#"{"d_sweep": [0]}"#).is_err());
        assert!(ReportConfig::from_json(r#"{"kernels": ["bogus"]}"#).is_err());
        assert!(ReportConfig::from_json(r#"{"threads_sweep": [0]}"#).is_err());
        assert!(ReportConfig::from_json(r#"{"runs": 0}"#).is_err());
        assert!(ReportConfig::quick().validate().is_ok());
        assert!(ReportConfig::full().validate().is_ok());
    }

    #[test]
    fn report_fingerprint_tracks_grid_axes_only() {
        let a = ReportConfig::quick();
        let mut b = ReportConfig::quick();
        b.out_dir = "/elsewhere".into();
        b.resume = false;
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = ReportConfig::quick();
        c.seed = 43;
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), ReportConfig::full().fingerprint());
    }

    #[test]
    fn experiment_config_validates() {
        assert!(ExperimentConfig::from_json(r#"{"n_features": 0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"p": 1.0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"train_frac": 1.5}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"kernel": {"kind": "bad"}}"#).is_err());
    }
}
