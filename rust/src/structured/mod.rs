//! Structured random projection subsystem.
//!
//! Every feature map in this crate spends its serving time on the same
//! primitive: projecting an input `x ∈ R^d` onto a stack of random
//! directions (`rows` Rademacher vectors for Random Maclaurin, `rows`
//! Gaussian frequencies for Random Fourier). Dense stacks cost
//! `O(rows · d)` per input; this module makes the primitive pluggable
//! and adds an `O(rows · log d)` alternative built from **HD blocks**
//! (seeded Rademacher diagonal `D` followed by an unnormalized fast
//! Walsh–Hadamard transform `H`, computed in place by
//! [`crate::linalg::fwht`]), the construction of Choromanski &
//! Sindhwani's *Recycling Randomness with Structure* and the structured
//! variants in Wacker et al.'s *Improved Random Features for Dot
//! Product Kernels*.
//!
//! Layers:
//!
//! * [`Projection`] — the trait: `project_into` (one input) and
//!   `project_batch` (row-chunked over the [`crate::parallel`] worker
//!   pool; bit-identical to the serial per-row routine for any thread
//!   count, like every other batch path in the crate).
//! * [`DenseProjection`] — the classic explicit matrix (streaming axpy
//!   for one vector, blocked GEMM for batches). The Random Maclaurin
//!   dense path is bit-identical to its pre-subsystem implementation
//!   (same layouts, same ascending-k accumulation); dense Random
//!   Fourier now accumulates in the same ascending-k order instead of
//!   its old per-row 4-lane dot, so seeded RFF outputs shift within
//!   float tolerance across versions (same seed still yields the same
//!   frequencies).
//! * [`StructuredProjection`] ([`hd`]) — chains of HD blocks with
//!   zero-padding to the next power of two, in three flavors:
//!   Rademacher recycling (`rademacher_*`, exact ±1 marginals), the
//!   Fastfood-style Gaussian chain (`gaussian_stack`, exact `N(0, σ²I)`
//!   marginals), and the SRHT row-subsampler (`srht`).
//! * [`ProjectionKind`] — the `dense | structured` knob surfaced by
//!   `config` (`"projection"`) and the CLI (`--projection`), consumed
//!   by [`crate::maclaurin::RmConfig`] and
//!   [`crate::rff::RandomFourier::sample_with`].
//!
//! **Statistics.** A row of `H·D` has entries `H[i, k]·d_k ∈ {±1}` with
//! `d` a fair sign vector, so each row is *exactly* a Rademacher vector
//! in distribution — structured projections inherit the dense maps'
//! marginal law, per-row unbiasedness (`E[⟨h, x⟩⟨h, y⟩] = ⟨x, y⟩`) and
//! the deterministic bound `|⟨h, x⟩| ≤ ‖x‖₁` that Lemma 8 of the paper
//! rests on. What changes is *joint* law: rows inside one block share
//! `d` and are correlated, which perturbs variance (concentration), not
//! means. See [`crate::maclaurin`] for how the Random Maclaurin sampler
//! assigns rows to blocks so its product-estimator stays exactly
//! unbiased at every order.

pub mod hd;

pub use hd::StructuredProjection;

use crate::linalg::{Matrix, SparseMatrix, SparseRow};
use crate::{Error, Result};

/// The `dense | structured` projection knob, threaded from the CLI /
/// config surface down to the samplers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProjectionKind {
    /// Explicit random matrix: `O(rows · d)` per input.
    #[default]
    Dense,
    /// HD-block chain (FWHT-based): `O(rows · log d)` per input.
    Structured,
}

impl ProjectionKind {
    /// Parse the CLI/config spelling.
    pub fn parse(s: &str) -> Result<ProjectionKind> {
        match s {
            "dense" => Ok(ProjectionKind::Dense),
            "structured" => Ok(ProjectionKind::Structured),
            other => Err(Error::Config(format!(
                "unknown projection {other:?} (expected dense|structured)"
            ))),
        }
    }

    /// Canonical spelling (inverse of [`ProjectionKind::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            ProjectionKind::Dense => "dense",
            ProjectionKind::Structured => "structured",
        }
    }
}

/// A fixed stack of random projection directions `w_1..w_rows ∈ R^d`:
/// `project_into` computes all `⟨w_r, x⟩` for one input.
///
/// This is step 2–3 of the paper's Algorithm 1 made pluggable: the
/// Random Maclaurin sampler draws its `ω_j ∈ {±1}^d` rows through an
/// implementation of this trait, and every statistical guarantee it
/// needs is stated *per row* — each row must be Rademacher (or, for
/// Fourier stacks, Gaussian) in marginal law, so per-feature
/// unbiasedness (Lemma 7) and the deterministic estimator bound
/// `|ω^T x| ≤ ‖x‖₁` behind Lemma 8's `C_Ω = p·f(pR²)` hold for any
/// implementation. Joint law across rows is implementation-specific:
/// correlations (HD blocks) perturb the Theorem 12 concentration
/// *constants*, never the means — see the module docs.
///
/// Implementations must make `project_batch` row `i` bit-identical to
/// `project_into` on row `i` (the crate-wide determinism contract:
/// batching and threading are scheduling, never semantics).
pub trait Projection: Send + Sync + std::fmt::Debug {
    /// Input dimensionality `d`.
    fn input_dim(&self) -> usize;

    /// Number of projection directions.
    fn rows(&self) -> usize;

    /// `out[r] = ⟨w_r, x⟩` (`out.len() == rows()`).
    fn project_into(&self, x: &[f32], out: &mut [f32]);

    /// Length of the caller-owned workspace slice the scratch entry
    /// points need (`0` when the implementation has no internal
    /// buffers — the dense matrix streams straight into `out`).
    fn scratch_len(&self) -> usize {
        0
    }

    /// [`Projection::project_into`] with caller-owned workspace: `work`
    /// must hold at least [`Projection::scratch_len`] elements
    /// (contents unspecified on entry and exit). Bit-identical to
    /// `project_into`; implementations with internal buffers override
    /// it so a reused workspace makes the call allocation-free.
    fn project_into_scratch(&self, x: &[f32], out: &mut [f32], _work: &mut [f32]) {
        self.project_into(x, out);
    }

    /// [`Projection::project_sparse_into`] with caller-owned workspace
    /// (same contract as [`Projection::project_into_scratch`]).
    fn project_sparse_into_scratch(&self, x: SparseRow<'_>, out: &mut [f32], _work: &mut [f32]) {
        self.project_sparse_into(x, out);
    }

    /// Approximate mul-add cost of one `project_into` call — the
    /// scheduling hint fed to
    /// [`crate::parallel::resolve_threads_for_work`].
    fn unit_work(&self) -> usize {
        self.rows().saturating_mul(self.input_dim()).max(1)
    }

    /// Project every row of `x`: returns `x.rows() × rows()`. Fans row
    /// blocks out over `threads` scoped workers (`0` = the global
    /// [`crate::parallel`] knob); every output row runs the identical
    /// serial routine, so results are bit-identical for any thread
    /// count.
    fn project_batch(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let (b, r) = (x.rows(), self.rows());
        let mut out = Matrix::zeros(b, r);
        if b == 0 || r == 0 {
            return out;
        }
        let work = b.saturating_mul(self.unit_work());
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        crate::parallel::par_chunks(threads, r, out.as_mut_slice(), |row0, block| {
            // One workspace per worker block: the per-row loop is
            // allocation-free in steady state (zero-length for dense
            // stacks, which never allocate to begin with).
            let mut work = vec![0.0f32; self.scratch_len()];
            for (i, out_row) in block.chunks_mut(r).enumerate() {
                self.project_into_scratch(x.row(row0 + i), out_row, &mut work);
            }
        });
        out
    }

    /// `out[r] = ⟨w_r, x⟩` for one CSR row. The default densifies and
    /// delegates (always equal to the dense path); [`DenseProjection`]
    /// overrides with an `O(rows · nnz)` kernel that is bit-identical
    /// to its zero-skipping dense loop.
    fn project_sparse_into(&self, x: SparseRow<'_>, out: &mut [f32]) {
        assert_eq!(x.dim, self.input_dim(), "input dim mismatch");
        let dense = x.to_dense();
        self.project_into(&dense, out);
    }

    /// Project every row of a CSR matrix (same contract as
    /// [`Projection::project_batch`]: any thread count is bit-identical
    /// to the serial per-row routine, and every row equals the dense
    /// path on the densified input).
    fn project_batch_sparse(&self, x: &SparseMatrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        let (b, r) = (x.rows(), self.rows());
        let mut out = Matrix::zeros(b, r);
        if b == 0 || r == 0 {
            return out;
        }
        // ~nnz · rows mul-adds across the whole batch for sparse-aware
        // implementations (the densifying default costs more; the hint
        // only steers scheduling).
        let work = x.nnz().max(b).saturating_mul(r);
        let threads = crate::parallel::resolve_threads_for_work(threads, b, work);
        crate::parallel::par_chunks(threads, r, out.as_mut_slice(), |row0, block| {
            let mut work = vec![0.0f32; self.scratch_len()];
            for (i, out_row) in block.chunks_mut(r).enumerate() {
                self.project_sparse_into_scratch(x.row(row0 + i), out_row, &mut work);
            }
        });
        out
    }
}

/// Explicit dense projection matrix, stored transposed (`d × rows`,
/// row-major) so one input streams it row by row and a batch multiplies
/// it as a single GEMM — exactly the layouts (and, for the Random
/// Maclaurin path, exactly the float results) of the pre-subsystem hot
/// paths. With i.i.d. Rademacher rows this *is* the paper's Algorithm 1
/// projection stack verbatim: independent rows, so the Theorem 12
/// concentration constants apply unchanged.
#[derive(Clone, Debug)]
pub struct DenseProjection {
    /// `d × rows` (column `r` is direction `w_r`).
    t: Matrix,
}

impl DenseProjection {
    /// Wrap a `d × rows` transposed direction matrix.
    pub fn from_transposed(t: Matrix) -> Self {
        DenseProjection { t }
    }

    /// Wrap a `rows × d` direction matrix (transposing it).
    pub fn from_rows_matrix(w: &Matrix) -> Self {
        DenseProjection { t: w.transpose() }
    }

    /// Expand a bit-packed Rademacher stack into the dense ±1 layout.
    pub fn from_rademacher(omegas: &crate::rng::RademacherMatrix) -> Self {
        let (rows, d) = (omegas.rows(), omegas.dim());
        let mut t = Matrix::zeros(d, rows);
        for r in 0..rows {
            for k in 0..d {
                t.set(k, r, omegas.sign(r, k));
            }
        }
        DenseProjection { t }
    }

    /// The underlying `d × rows` matrix.
    pub fn transposed(&self) -> &Matrix {
        &self.t
    }
}

impl Projection for DenseProjection {
    fn input_dim(&self) -> usize {
        self.t.rows()
    }

    fn rows(&self) -> usize {
        self.t.cols()
    }

    fn project_into(&self, x: &[f32], out: &mut [f32]) {
        let _span = crate::obs::span("project.dense");
        assert_eq!(x.len(), self.input_dim(), "input dim mismatch");
        assert_eq!(out.len(), self.rows(), "output len mismatch");
        out.fill(0.0);
        // out[r] = Σ_k x[k] · t[k, r]; accumulating row k of the
        // transposed matrix is the streaming direction, and the
        // ascending-k order matches the GEMM accumulation order, so
        // single-vector and batch projections agree bit-for-bit.
        for (k, &xk) in x.iter().enumerate() {
            if xk != 0.0 {
                crate::linalg::axpy(xk, self.t.row(k), out);
            }
        }
    }

    fn project_batch(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols(), self.input_dim(), "input dim mismatch");
        if self.rows() == 0 {
            return Matrix::zeros(x.rows(), 0);
        }
        x.matmul_threads(&self.t, threads).expect("inner dims agree")
    }

    /// The `O(rows · nnz)` fast path: accumulate `v_k · t[k, ·]` over
    /// the stored entries in ascending-`k` order — exactly the terms
    /// (and the order) the dense loop and the GEMM keep after their
    /// `x[k] != 0` skips, so the output is bit-identical to the dense
    /// path on the densified row.
    fn project_sparse_into(&self, x: SparseRow<'_>, out: &mut [f32]) {
        let _span = crate::obs::span("project.dense");
        assert_eq!(x.dim, self.input_dim(), "input dim mismatch");
        assert_eq!(out.len(), self.rows(), "output len mismatch");
        out.fill(0.0);
        for (&k, &xk) in x.indices.iter().zip(x.values) {
            if xk != 0.0 {
                crate::linalg::axpy(xk, self.t.row(k as usize), out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{RademacherMatrix, Rng};

    fn random_batch(rows: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        Matrix::from_vec(rows, d, (0..rows * d).map(|_| rng.f32() - 0.5).collect()).unwrap()
    }

    #[test]
    fn kind_parses_and_round_trips() {
        assert_eq!(ProjectionKind::parse("dense").unwrap(), ProjectionKind::Dense);
        assert_eq!(ProjectionKind::parse("structured").unwrap(), ProjectionKind::Structured);
        // No undocumented aliases: only the two documented spellings
        // (which round-trip through as_str) parse.
        assert!(ProjectionKind::parse("fwht").is_err());
        assert!(ProjectionKind::parse("srht").is_err());
        assert!(ProjectionKind::parse("fancy").is_err());
        for kind in [ProjectionKind::Dense, ProjectionKind::Structured] {
            assert_eq!(ProjectionKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert_eq!(ProjectionKind::default(), ProjectionKind::Dense);
    }

    #[test]
    fn dense_matches_rademacher_project_all() {
        let mut rng = Rng::seed_from(1);
        let (rows, d) = (9, 37);
        let omegas = RademacherMatrix::sample(rows, d, &mut rng);
        let p = DenseProjection::from_rademacher(&omegas);
        assert_eq!(p.input_dim(), d);
        assert_eq!(p.rows(), rows);
        let x: Vec<f32> = (0..d).map(|k| (k as f32 * 0.13).sin()).collect();
        let mut got = vec![0.0f32; rows];
        p.project_into(&x, &mut got);
        let mut want = vec![0.0f32; rows];
        omegas.project_all(&x, &mut want);
        for r in 0..rows {
            assert!((got[r] - want[r]).abs() < 1e-4, "row {r}: {} vs {}", got[r], want[r]);
        }
    }

    #[test]
    fn dense_batch_rows_equal_single_bitwise() {
        let mut rng = Rng::seed_from(2);
        let (rows, d, b) = (17, 12, 7);
        let omegas = RademacherMatrix::sample(rows, d, &mut rng);
        let p = DenseProjection::from_rademacher(&omegas);
        let x = random_batch(b, d, 3);
        let z = p.project_batch(&x, 1);
        for i in 0..b {
            let mut single = vec![0.0f32; rows];
            p.project_into(x.row(i), &mut single);
            assert_eq!(z.row(i), &single[..], "row {i}");
        }
        for threads in [2usize, 5, 64] {
            assert_eq!(p.project_batch(&x, threads), z);
        }
    }

    fn sparse_batch(rows: usize, d: usize, keep: f64, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let mut m = Matrix::zeros(rows, d);
        for i in 0..rows {
            for j in 0..d {
                if rng.f64() < keep {
                    m.set(i, j, rng.f32() - 0.5);
                }
            }
        }
        m
    }

    #[test]
    fn dense_projection_sparse_rows_equal_dense_bitwise() {
        // The tentpole parity contract at the projection layer: CSR rows
        // through the O(rows·nnz) kernel equal the dense zero-skipping
        // loop and the GEMM batch, bit for bit, at any thread count.
        let mut rng = Rng::seed_from(7);
        let (rows, d, b) = (23, 37, 9);
        let omegas = RademacherMatrix::sample(rows, d, &mut rng);
        let p = DenseProjection::from_rademacher(&omegas);
        let x = sparse_batch(b, d, 0.15, 8);
        let sx = SparseMatrix::from_dense(&x);
        let dense = p.project_batch(&x, 1);
        for i in 0..b {
            let mut got = vec![0.0f32; rows];
            p.project_sparse_into(sx.row(i), &mut got);
            assert_eq!(&got[..], dense.row(i), "row {i}");
        }
        for threads in [1usize, 2, 5, 64] {
            assert_eq!(p.project_batch_sparse(&sx, threads), dense, "threads {threads}");
        }
    }

    #[test]
    fn structured_projection_sparse_default_matches_dense() {
        // StructuredProjection keeps the densifying default — still
        // exactly the dense result (FWHT needs the full buffer anyway).
        let mut rng = Rng::seed_from(9);
        let (d, b) = (24usize, 5usize);
        let p = StructuredProjection::gaussian_stack(d, 32, 1.0, &mut rng);
        let x = sparse_batch(b, d, 0.2, 10);
        let sx = SparseMatrix::from_dense(&x);
        assert_eq!(p.project_batch_sparse(&sx, 2), p.project_batch(&x, 1));
    }

    #[test]
    fn empty_projection_yields_zero_columns() {
        let p = DenseProjection::from_transposed(Matrix::zeros(4, 0));
        let z = p.project_batch(&random_batch(3, 4, 4), 2);
        assert_eq!((z.rows(), z.cols()), (3, 0));
    }
}
