//! HD-block chains: the FWHT-backed [`StructuredProjection`].
//!
//! One **HD block** realizes `n` projection directions (`n` = the input
//! dim zero-padded to the next power of two) from `n` random bits:
//!
//! ```text
//! y = H · (D x)                        (Rademacher mode)
//! y = (1/√n) · H · G · Π · H · (D x)   (Gaussian / Fastfood mode)
//! ```
//!
//! with `D` a seeded Rademacher diagonal, `H` the *unnormalized*
//! Walsh–Hadamard transform ([`crate::linalg::fwht`], `O(n log n)`),
//! `Π` a random permutation and `G` a Gaussian diagonal (the `1/√n`
//! normalization and the target standard deviation are folded into
//! `G`). A projection needing `rows` directions chains
//! `⌈rows / n⌉`-ish independently seeded blocks and taps the slots it
//! needs, so the per-input cost is `O(blocks · n log n)` instead of the
//! dense `O(rows · n)`.
//!
//! Marginals are exact in both modes:
//! * Rademacher: row `i` of `H D` has entries `H[i,k] d_k ∈ {±1}` with
//!   iid fair signs — exactly a Rademacher vector, so
//!   `E[⟨h, x⟩⟨h, y⟩] = ⟨x, y⟩` and `|⟨h, x⟩| ≤ ‖x‖₁` hold exactly as
//!   for dense stacks.
//! * Gaussian: conditioned on `D` and `Π`, row `i` of
//!   `(1/√n) H G Π H D` is `w` with `Cov(w_k, w_l) = σ² δ_{kl}` (the
//!   inner `H D` has orthogonal ±1 columns of norm `√n`), i.e. exactly
//!   `N(0, σ² I_n)` — the Fastfood argument of Le, Sarlós & Smola made
//!   exact by conditioning.
//!
//! Rows *within* a block share randomness and are correlated; rows in
//! different blocks are independent. Callers that multiply projections
//! together (Random Maclaurin's order-`N` products) must therefore
//! place the factors of one product in distinct blocks —
//! [`StructuredProjection::rademacher_for_segments`] encodes exactly
//! that layout; see its docs.

use super::Projection;
use crate::artifact::WeightStore;
use crate::linalg::{fwht, next_pow2, SparseRow};
use crate::rng::Rng;

/// One seeded HD block plus the output taps it serves.
///
/// All random state lives in [`WeightStore`]s (ISSUE 8): freshly
/// sampled blocks own their vectors; blocks of a loaded `RFDM0003`
/// artifact are zero-copy views into the shared region; *recycled*
/// blocks ([`StructuredProjection::rademacher_for_segments_opts`]) are
/// aliased views into one shared pool.
#[derive(Clone, Debug)]
pub(crate) struct HdBlock {
    /// Rademacher diagonal `D` (±1), length `n`.
    pub(crate) signs: WeightStore<f32>,
    /// Gaussian mode: permutation `Π` and gain diagonal `G` applied
    /// between two FWHTs (`1/√n` and the target std folded into the
    /// gains). `None` = single-HD Rademacher mode.
    pub(crate) perm_gain: Option<(WeightStore<u32>, WeightStore<f32>)>,
    /// Interleaved `(slot in the transformed buffer, global output
    /// row)` pairs — flat `u32`s so the store layout matches the
    /// serialized section exactly.
    pub(crate) taps: WeightStore<u32>,
    /// Uniform output scale (1 for HD blocks, `1/√k` for SRHT).
    pub(crate) scale: f32,
}

/// Build the interleaved tap store from `(slot, row)` pairs.
fn tap_store(pairs: impl Iterator<Item = (u32, u32)>) -> WeightStore<u32> {
    WeightStore::from_vec(pairs.flat_map(|(s, r)| [s, r]).collect())
}

impl HdBlock {
    /// Run the chain on (implicitly zero-padded) `x` and scatter the
    /// tapped slots into `out`. `buf`/`tmp` are caller-owned `n`-length
    /// scratch.
    fn project(&self, x: &[f32], buf: &mut [f32], tmp: &mut [f32], out: &mut [f32]) {
        let signs = self.signs.as_slice();
        for (k, &xk) in x.iter().enumerate() {
            buf[k] = xk * signs[k];
        }
        buf[x.len()..].fill(0.0);
        self.finish(buf, tmp, out);
    }

    /// CSR twin of [`HdBlock::project`]: only the stored entries are
    /// multiplied by the diagonal (zeros scatter nothing), so the
    /// `D x` pass costs `O(nnz)` instead of `O(d)`. Equal to the dense
    /// chain on the densified row — the only representational
    /// difference is the sign of zeros (`0 · −1 = −0` on the dense
    /// path), which `f32` equality ignores (the sparse parity
    /// contract's one legal divergence).
    fn project_sparse(
        &self,
        x: crate::linalg::SparseRow<'_>,
        buf: &mut [f32],
        tmp: &mut [f32],
        out: &mut [f32],
    ) {
        buf.fill(0.0);
        let signs = self.signs.as_slice();
        for (&k, &v) in x.indices.iter().zip(x.values) {
            let k = k as usize;
            buf[k] = v * signs[k];
        }
        self.finish(buf, tmp, out);
    }

    /// Shared tail of both entry paths: the FWHT chain over the
    /// diagonal-multiplied buffer, then the output taps.
    fn finish(&self, buf: &mut [f32], tmp: &mut [f32], out: &mut [f32]) {
        fwht(buf);
        let src: &[f32] = match &self.perm_gain {
            Some((perm, gain)) => {
                for (l, (&p, &g)) in
                    perm.as_slice().iter().zip(gain.as_slice()).enumerate()
                {
                    tmp[l] = g * buf[p as usize];
                }
                fwht(tmp);
                tmp
            }
            None => buf,
        };
        for t in self.taps.as_slice().chunks_exact(2) {
            out[t[1] as usize] = self.scale * src[t[0] as usize];
        }
    }

    /// FWHT mul-adds this block costs per input.
    fn work(&self) -> usize {
        let n = self.signs.len();
        let log_n = n.trailing_zeros() as usize + 1;
        let passes = if self.perm_gain.is_some() { 2 } else { 1 };
        passes * n * log_n + n
    }
}

fn sample_signs(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.sign() as f32).collect()
}

/// A structured (FWHT-based) projection: input dim `d`, padded length
/// `n = next_pow2(d)`, `rows` output directions served by a list of
/// independently seeded [`HdBlock`]s. Construction is a pure function
/// of the constructor arguments and the RNG stream, which is what makes
/// seed-only serialization ([`crate::maclaurin::serialize`]) exact.
#[derive(Clone, Debug)]
pub struct StructuredProjection {
    d: usize,
    n: usize,
    rows: usize,
    blocks: Vec<HdBlock>,
}

impl StructuredProjection {
    /// Rademacher rows for segmented *products* (the Random Maclaurin
    /// layout). `offsets` are the feature→row offsets of
    /// [`crate::maclaurin::RandomMaclaurin`]: feature `i` owns rows
    /// `offsets[i]..offsets[i+1]` and multiplies them together.
    ///
    /// Layout: factor position `j` of every feature lands in **layer**
    /// `j`, and each layer is served by its own freshly seeded HD
    /// block(s) (chunked by `n` when a layer needs more than `n` rows).
    /// The rows of one feature therefore all sit in *distinct, mutually
    /// independent* blocks, so the expectation of the feature's product
    /// factorizes and the Random Maclaurin estimator stays **exactly
    /// unbiased at every order** — the only statistical change vs dense
    /// stacks is cross-feature correlation within a layer block, which
    /// affects variance (see the Gram-envelope tests), not means.
    pub fn rademacher_for_segments(d: usize, offsets: &[u32], rng: &mut Rng) -> Self {
        assert!(d > 0, "input dim must be positive");
        assert!(!offsets.is_empty(), "offsets must contain at least the leading 0");
        let n = next_pow2(d);
        let rows = *offsets.last().expect("non-empty") as usize;
        let mut blocks = Vec::new();
        let mut layer = 0u32;
        loop {
            // Rows at factor position `layer`, in feature order. Counts
            // are non-increasing in `layer`, so the first empty layer
            // ends the loop.
            let outs: Vec<u32> = (0..offsets.len() - 1)
                .filter(|&i| offsets[i + 1] - offsets[i] > layer)
                .map(|i| offsets[i] + layer)
                .collect();
            if outs.is_empty() {
                break;
            }
            for chunk in outs.chunks(n) {
                blocks.push(HdBlock {
                    signs: WeightStore::from_vec(sample_signs(n, rng)),
                    perm_gain: None,
                    taps: tap_store(chunk.iter().enumerate().map(|(s, &r)| (s as u32, r))),
                    scale: 1.0,
                });
            }
            layer += 1;
        }
        StructuredProjection { d, n, rows, blocks }
    }

    /// [`Self::rademacher_for_segments`] with optional **randomness
    /// recycling** (Choromanski & Sindhwani). `recycle = false`
    /// delegates verbatim — bit-identical numerics, same RNG stream.
    ///
    /// Recycled mode samples **one** sign pool of length `n`, stores it
    /// doubled (`2n`), and gives each block the rotated zero-copy view
    /// `pool[δ_b .. δ_b + n)` for a fresh uniform offset `δ_b` — one
    /// `u64` draw per block instead of `n` sign draws. Each block's
    /// diagonal is marginally a perfectly fair sign pattern *given the
    /// pool is one* (each coordinate is a fixed ±1 pool entry at a
    /// uniformly rotated position), and the serializer stores the pool
    /// once, shrinking sampled state from `O(blocks · n)` to `O(n)`.
    /// Cross-block couplings are introduced (rotations of one pool),
    /// which biases order-≥2 Maclaurin products by `O(1/n)` — see
    /// ARCHITECTURE.md for the math; hence the knob defaults off.
    pub fn rademacher_for_segments_opts(
        d: usize,
        offsets: &[u32],
        recycle: bool,
        rng: &mut Rng,
    ) -> Self {
        if !recycle {
            return Self::rademacher_for_segments(d, offsets, rng);
        }
        assert!(d > 0, "input dim must be positive");
        assert!(!offsets.is_empty(), "offsets must contain at least the leading 0");
        let n = next_pow2(d);
        let rows = *offsets.last().expect("non-empty") as usize;
        // The doubled pool: a rotation δ ∈ [0, n) is the contiguous
        // window [δ, δ + n) — no wraparound indexing in the hot path.
        let base = sample_signs(n, rng);
        let mut doubled = base.clone();
        doubled.extend_from_slice(&base);
        let pool = WeightStore::from_vec(doubled);
        let mut blocks = Vec::new();
        let mut layer = 0u32;
        loop {
            let outs: Vec<u32> = (0..offsets.len() - 1)
                .filter(|&i| offsets[i + 1] - offsets[i] > layer)
                .map(|i| offsets[i] + layer)
                .collect();
            if outs.is_empty() {
                break;
            }
            for chunk in outs.chunks(n) {
                let delta = rng.below(n as u64) as usize;
                blocks.push(HdBlock {
                    signs: pool.view(delta, n),
                    perm_gain: None,
                    taps: tap_store(chunk.iter().enumerate().map(|(s, &r)| (s as u32, r))),
                    scale: 1.0,
                });
            }
            layer += 1;
        }
        StructuredProjection { d, n, rows, blocks }
    }

    /// Plain stacked Rademacher rows: row `r` = slot `r % n` of block
    /// `r / n`. The right layout when every row is consumed on its own
    /// (no products), e.g. SRHT-style sketching experiments.
    pub fn rademacher_stack(d: usize, rows: usize, rng: &mut Rng) -> Self {
        assert!(d > 0, "input dim must be positive");
        let n = next_pow2(d);
        let mut blocks = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let take = (rows - start).min(n);
            blocks.push(HdBlock {
                signs: WeightStore::from_vec(sample_signs(n, rng)),
                perm_gain: None,
                taps: tap_store((0..take).map(|s| (s as u32, (start + s) as u32))),
                scale: 1.0,
            });
            start += take;
        }
        StructuredProjection { d, n, rows, blocks }
    }

    /// Fastfood-style Gaussian rows, marginally exactly `N(0, std² I)`:
    /// the frequency stack of structured Random Fourier Features
    /// ([`crate::rff::RandomFourier::sample_with`]).
    pub fn gaussian_stack(d: usize, rows: usize, std: f64, rng: &mut Rng) -> Self {
        assert!(d > 0, "input dim must be positive");
        let n = next_pow2(d);
        let inv_sqrt_n = 1.0 / (n as f64).sqrt();
        let mut blocks = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let take = (rows - start).min(n);
            let signs = sample_signs(n, rng);
            let mut perm: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut perm);
            let gain: Vec<f32> =
                (0..n).map(|_| (std * rng.normal() * inv_sqrt_n) as f32).collect();
            blocks.push(HdBlock {
                signs: WeightStore::from_vec(signs),
                perm_gain: Some((WeightStore::from_vec(perm), WeightStore::from_vec(gain))),
                taps: tap_store((0..take).map(|s| (s as u32, (start + s) as u32))),
                scale: 1.0,
            });
            start += take;
        }
        StructuredProjection { d, n, rows, blocks }
    }

    /// [`Self::gaussian_stack`] with optional randomness recycling.
    /// `recycle = false` delegates verbatim (bit-identical numerics).
    ///
    /// Recycled mode samples `(Π, G)` **once** and aliases the pair
    /// into every block (zero-copy `WeightStore` views, serialized
    /// once); the diagonals `D_b` stay fresh per block. Conditioned on
    /// `(Π, G)`, each block's rows are exactly `N(0, σ²)` marginally —
    /// the joint per-block law `(D_b, Π, G)` equals the fresh-sample
    /// law because `D_b ⊥ (Π, G)` — so the structured RFF estimator
    /// stays **exactly unbiased**; only cross-block independence is
    /// traded away (variance, not mean). Sampled state drops from
    /// `O(blocks · n)` Gaussians to `O(n)`.
    pub fn gaussian_stack_opts(
        d: usize,
        rows: usize,
        std: f64,
        recycle: bool,
        rng: &mut Rng,
    ) -> Self {
        if !recycle {
            return Self::gaussian_stack(d, rows, std, rng);
        }
        assert!(d > 0, "input dim must be positive");
        let n = next_pow2(d);
        let inv_sqrt_n = 1.0 / (n as f64).sqrt();
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        let gain: Vec<f32> = (0..n).map(|_| (std * rng.normal() * inv_sqrt_n) as f32).collect();
        let perm = WeightStore::from_vec(perm);
        let gain = WeightStore::from_vec(gain);
        let mut blocks = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let take = (rows - start).min(n);
            blocks.push(HdBlock {
                signs: WeightStore::from_vec(sample_signs(n, rng)),
                perm_gain: Some((perm.clone(), gain.clone())),
                taps: tap_store((0..take).map(|s| (s as u32, (start + s) as u32))),
                scale: 1.0,
            });
            start += take;
        }
        StructuredProjection { d, n, rows, blocks }
    }

    /// The subsampled randomized Hadamard transform: `k` *distinct*
    /// rows per block, scaled by `1/√k` so `E[‖Φx‖²] = ‖x‖²` (the JL
    /// isometry normalization).
    pub fn srht(d: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(d > 0 && k > 0, "dims must be positive");
        let n = next_pow2(d);
        let scale = (1.0 / (k as f64).sqrt()) as f32;
        let mut blocks = Vec::new();
        let mut start = 0usize;
        while start < k {
            let take = (k - start).min(n);
            let slots = rng.sample_indices(n, take);
            blocks.push(HdBlock {
                signs: WeightStore::from_vec(sample_signs(n, rng)),
                perm_gain: None,
                taps: tap_store(
                    slots.iter().enumerate().map(|(s, &slot)| (slot as u32, (start + s) as u32)),
                ),
                scale,
            });
            start += take;
        }
        StructuredProjection { d, n, rows: k, blocks }
    }

    /// Reassemble from per-block stores — the artifact instantiation
    /// path ([`crate::artifact::MapArtifact::instantiate`]); the blocks
    /// borrow the shared region zero-copy.
    pub(crate) fn from_blocks(d: usize, rows: usize, blocks: Vec<HdBlock>) -> Self {
        StructuredProjection { d, n: next_pow2(d), rows, blocks }
    }

    /// The backing blocks (artifact serializer).
    pub(crate) fn blocks(&self) -> &[HdBlock] {
        &self.blocks
    }

    /// Padded (power-of-two) working length.
    pub fn padded_dim(&self) -> usize {
        self.n
    }

    /// Number of HD blocks backing the stack.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Second-scratch length: `n` only when some block runs the
    /// two-FWHT Gaussian chain; Rademacher-only stacks (the whole
    /// Random Maclaurin path) never touch `tmp`.
    fn tmp_len(&self) -> usize {
        if self.blocks.iter().any(|b| b.perm_gain.is_some()) {
            self.n
        } else {
            0
        }
    }
}

impl Projection for StructuredProjection {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn unit_work(&self) -> usize {
        self.blocks.iter().map(HdBlock::work).sum::<usize>().max(1)
    }

    /// FWHT pad + (Gaussian-chain) permutation buffer.
    fn scratch_len(&self) -> usize {
        self.n + self.tmp_len()
    }

    fn project_into(&self, x: &[f32], out: &mut [f32]) {
        let mut work = vec![0.0f32; self.scratch_len()];
        self.project_into_scratch(x, out, &mut work);
    }

    fn project_into_scratch(&self, x: &[f32], out: &mut [f32], work: &mut [f32]) {
        let _span = crate::obs::span("project.structured");
        assert_eq!(x.len(), self.d, "input dim mismatch");
        assert_eq!(out.len(), self.rows, "output len mismatch");
        let (buf, rest) = work.split_at_mut(self.n);
        let tmp = &mut rest[..self.tmp_len()];
        for block in &self.blocks {
            block.project(x, buf, tmp, out);
        }
    }

    /// `O(nnz + n log n)` per block: the diagonal pass scatters only
    /// the stored entries (see [`HdBlock::project_sparse`]); the FWHT
    /// chain needs the full padded buffer either way. Equal to the
    /// dense path on the densified row.
    fn project_sparse_into(&self, x: SparseRow<'_>, out: &mut [f32]) {
        let mut work = vec![0.0f32; self.scratch_len()];
        self.project_sparse_into_scratch(x, out, &mut work);
    }

    fn project_sparse_into_scratch(&self, x: SparseRow<'_>, out: &mut [f32], work: &mut [f32]) {
        let _span = crate::obs::span("project.structured");
        assert_eq!(x.dim, self.d, "input dim mismatch");
        assert_eq!(out.len(), self.rows, "output len mismatch");
        let (buf, rest) = work.split_at_mut(self.n);
        let tmp = &mut rest[..self.tmp_len()];
        for block in &self.blocks {
            block.project_sparse(x, buf, tmp, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, Matrix};

    fn unit_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }

    /// Recover direction `r` by projecting the basis vectors.
    fn direction(p: &StructuredProjection, r: usize) -> Vec<f32> {
        let d = p.input_dim();
        let mut w = vec![0.0f32; d];
        let mut out = vec![0.0f32; p.rows()];
        for k in 0..d {
            let mut e = vec![0.0f32; d];
            e[k] = 1.0;
            p.project_into(&e, &mut out);
            w[k] = out[r];
        }
        w
    }

    #[test]
    fn rademacher_rows_have_pm_one_entries() {
        // Each HD row must be a genuine ±1 sign pattern — the property
        // the Lemma 8 bound and the marginal-law argument rest on.
        let mut rng = Rng::seed_from(1);
        for d in [1usize, 3, 8, 13, 64] {
            let p = StructuredProjection::rademacher_stack(d, 2 * d + 3, &mut rng);
            for r in 0..p.rows() {
                for (k, &w) in direction(&p, r).iter().enumerate() {
                    assert!(w == 1.0 || w == -1.0, "d={d} row={r} k={k}: {w}");
                }
            }
        }
    }

    #[test]
    fn rademacher_rows_preserve_dot_products_in_expectation() {
        // E[⟨h, x⟩⟨h, y⟩] = ⟨x, y⟩ averaged over seeds (Lemma 6 analog).
        let d = 24;
        let x = unit_vec(d, 10);
        let y = unit_vec(d, 11);
        let exact = dot(&x, &y) as f64;
        let trials = 3000;
        let mut acc = 0.0f64;
        let mut count = 0usize;
        for s in 0..trials {
            let mut rng = Rng::seed_from(1000 + s);
            let p = StructuredProjection::rademacher_stack(d, 4, &mut rng);
            let mut px = vec![0.0f32; 4];
            let mut py = vec![0.0f32; 4];
            p.project_into(&x, &mut px);
            p.project_into(&y, &mut py);
            for r in 0..4 {
                acc += (px[r] * py[r]) as f64;
                count += 1;
            }
        }
        let mean = acc / count as f64;
        assert!((mean - exact).abs() < 0.07, "mean {mean} vs exact {exact}");
    }

    #[test]
    fn segments_layout_separates_each_features_rows() {
        // offsets for orders [2, 0, 3, 1]: features' factor rows must
        // land in per-layer blocks, all rows covered exactly once.
        let offsets = [0u32, 2, 2, 5, 6];
        let mut rng = Rng::seed_from(3);
        let p = StructuredProjection::rademacher_for_segments(11, &offsets, &mut rng);
        assert_eq!(p.rows(), 6);
        // Layers: 0 → rows {0, 2, 5}, 1 → {1, 3}, 2 → {4}; n = 16 so one
        // block per layer.
        assert_eq!(p.n_blocks(), 3);
        // Every output row is written (projections of a dense input are
        // nonzero with prob. 1; check they're all ±-sums, i.e. touched).
        let x = unit_vec(11, 4);
        let mut out = vec![f32::NAN; 6];
        p.project_into(&x, &mut out);
        assert!(out.iter().all(|v| v.is_finite()), "{out:?}");
    }

    #[test]
    fn segments_rows_match_fresh_rademacher_marginals() {
        // Rows recovered from the segments layout are ±1 patterns too.
        let offsets = [0u32, 1, 3, 6, 10];
        let mut rng = Rng::seed_from(5);
        let p = StructuredProjection::rademacher_for_segments(7, &offsets, &mut rng);
        for r in 0..p.rows() {
            for &w in &direction(&p, r) {
                assert!(w == 1.0 || w == -1.0);
            }
        }
    }

    #[test]
    fn gaussian_rows_have_standard_normal_marginals() {
        // Entries of the Fastfood rows are N(0, std²) marginally:
        // check mean/variance over many seeded blocks.
        let d = 16;
        let std = 1.5f64;
        let mut acc = 0.0f64;
        let mut acc2 = 0.0f64;
        let mut count = 0usize;
        for s in 0..400 {
            let mut rng = Rng::seed_from(50 + s);
            let p = StructuredProjection::gaussian_stack(d, 8, std, &mut rng);
            for r in 0..8 {
                for &w in &direction(&p, r) {
                    acc += w as f64;
                    acc2 += (w as f64) * w as f64;
                    count += 1;
                }
            }
        }
        let mean = acc / count as f64;
        let var = acc2 / count as f64 - mean * mean;
        assert!(mean.abs() < 0.06, "mean {mean}");
        assert!((var - std * std).abs() < 0.25, "var {var} vs {}", std * std);
    }

    #[test]
    fn srht_is_an_expected_isometry() {
        // E[‖Φx‖²] = ‖x‖² over seeds.
        let d = 20;
        let k = 12;
        let x = unit_vec(d, 21);
        let mut acc = 0.0f64;
        let trials = 2000;
        for s in 0..trials {
            let mut rng = Rng::seed_from(300 + s);
            let p = StructuredProjection::srht(d, k, &mut rng);
            let mut out = vec![0.0f32; k];
            p.project_into(&x, &mut out);
            acc += out.iter().map(|&v| (v as f64) * v as f64).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - 1.0).abs() < 0.05, "E‖Φx‖² = {mean}");
    }

    #[test]
    fn srht_taps_distinct_rows_per_block() {
        let mut rng = Rng::seed_from(7);
        let p = StructuredProjection::srht(8, 5, &mut rng);
        assert_eq!(p.n_blocks(), 1);
        let mut slots: Vec<u32> =
            p.blocks[0].taps.as_slice().chunks_exact(2).map(|t| t[0]).collect();
        slots.sort_unstable();
        slots.dedup();
        assert_eq!(slots.len(), 5, "SRHT slots must be distinct");
    }

    #[test]
    fn batch_is_bit_identical_to_single_and_across_threads() {
        let mut rng = Rng::seed_from(9);
        let d = 13;
        let p = StructuredProjection::rademacher_stack(d, 40, &mut rng);
        let rows: Vec<Vec<f32>> = (0..9).map(|i| unit_vec(d, 40 + i)).collect();
        let x = Matrix::from_rows(&rows).unwrap();
        let z = p.project_batch(&x, 1);
        for i in 0..9 {
            let mut single = vec![0.0f32; 40];
            p.project_into(x.row(i), &mut single);
            assert_eq!(z.row(i), &single[..], "row {i}");
        }
        for threads in [2usize, 3, 64] {
            assert_eq!(p.project_batch(&x, threads), z);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let build = || {
            StructuredProjection::gaussian_stack(10, 24, 0.7, &mut Rng::seed_from(77))
        };
        let (a, b) = (build(), build());
        let x = unit_vec(10, 78);
        let (mut oa, mut ob) = (vec![0.0f32; 24], vec![0.0f32; 24]);
        a.project_into(&x, &mut oa);
        b.project_into(&x, &mut ob);
        assert_eq!(oa, ob);
    }

    #[test]
    fn scratch_and_sparse_paths_match_dense_projection() {
        // project_into_scratch is project_into with relocated buffers;
        // the CSR scatter path equals the densified chain (up to the
        // sign of zeros, which f32 equality ignores).
        let mut rng = Rng::seed_from(31);
        for p in [
            StructuredProjection::rademacher_stack(13, 20, &mut rng),
            StructuredProjection::gaussian_stack(13, 20, 0.8, &mut rng),
        ] {
            let mut x = vec![0.0f32; 13];
            for (k, v) in x.iter_mut().enumerate() {
                if k % 3 == 0 {
                    *v = (k as f32 * 0.37).sin();
                }
            }
            let mut plain = vec![0.0f32; 20];
            p.project_into(&x, &mut plain);
            let mut work = vec![0.0f32; p.scratch_len()];
            let mut scratched = vec![0.0f32; 20];
            p.project_into_scratch(&x, &mut scratched, &mut work);
            assert_eq!(plain, scratched);
            // Reuse with stale contents must not leak between calls.
            p.project_into_scratch(&x, &mut scratched, &mut work);
            assert_eq!(plain, scratched);

            let m = Matrix::from_rows(&[x.clone()]).unwrap();
            let sm = crate::linalg::SparseMatrix::from_dense(&m);
            let mut sparse = vec![0.0f32; 20];
            p.project_sparse_into(sm.row(0), &mut sparse);
            assert_eq!(plain, sparse);
            let mut sparse2 = vec![f32::NAN; 20];
            p.project_sparse_into_scratch(sm.row(0), &mut sparse2, &mut work);
            assert_eq!(plain, sparse2);
        }
    }

    #[test]
    fn opts_with_recycle_off_are_bit_identical_to_the_plain_constructors() {
        // The knob's default-off contract: same RNG stream, same
        // blocks, same outputs, bit for bit.
        let offsets = [0u32, 2, 5, 5, 9];
        let x = unit_vec(11, 90);
        let a = StructuredProjection::rademacher_for_segments(11, &offsets, &mut Rng::seed_from(8));
        let b = StructuredProjection::rademacher_for_segments_opts(
            11,
            &offsets,
            false,
            &mut Rng::seed_from(8),
        );
        let (mut oa, mut ob) = (vec![0.0f32; 9], vec![0.0f32; 9]);
        a.project_into(&x, &mut oa);
        b.project_into(&x, &mut ob);
        assert_eq!(oa, ob);

        let xg = unit_vec(13, 91);
        let g = StructuredProjection::gaussian_stack(13, 24, 0.9, &mut Rng::seed_from(9));
        let g2 =
            StructuredProjection::gaussian_stack_opts(13, 24, 0.9, false, &mut Rng::seed_from(9));
        let (mut og, mut og2) = (vec![0.0f32; 24], vec![0.0f32; 24]);
        g.project_into(&xg, &mut og);
        g2.project_into(&xg, &mut og2);
        assert_eq!(og, og2);
    }

    #[test]
    fn recycled_segments_share_one_sign_pool_and_stay_pm_one() {
        let offsets = [0u32, 2, 4, 7, 9];
        let mut rng = Rng::seed_from(21);
        let p = StructuredProjection::rademacher_for_segments_opts(10, &offsets, true, &mut rng);
        assert!(p.n_blocks() >= 2, "layout needs several layers to recycle across");
        // Zero-copy aliasing: every block's signs view the same backing.
        let mut ids: Vec<usize> = p.blocks.iter().map(|b| b.signs.backing_id()).collect();
        ids.dedup();
        assert_eq!(ids.len(), 1, "recycled blocks must alias one pool");
        // And the recovered rows are still genuine ±1 patterns.
        for r in 0..p.rows() {
            for &w in &direction(&p, r) {
                assert!(w == 1.0 || w == -1.0, "row {r}: {w}");
            }
        }
    }

    #[test]
    fn recycled_gaussian_blocks_share_perm_gain_and_keep_marginals() {
        // Shared (Π, G), fresh D per block: still N(0, std²) marginals.
        let d = 16;
        let std = 1.2f64;
        let mut acc = 0.0f64;
        let mut acc2 = 0.0f64;
        let mut count = 0usize;
        for s in 0..400 {
            let mut rng = Rng::seed_from(700 + s);
            let p = StructuredProjection::gaussian_stack_opts(d, 40, std, true, &mut rng);
            assert!(p.n_blocks() >= 2);
            let gains: Vec<usize> = p
                .blocks
                .iter()
                .map(|b| b.perm_gain.as_ref().expect("gaussian block").1.backing_id())
                .collect();
            assert!(gains.windows(2).all(|w| w[0] == w[1]), "gain pool must be shared");
            if s < 40 {
                for r in 0..p.rows() {
                    for &w in &direction(&p, r) {
                        acc += w as f64;
                        acc2 += (w as f64) * w as f64;
                        count += 1;
                    }
                }
            }
        }
        let mean = acc / count as f64;
        let var = acc2 / count as f64 - mean * mean;
        assert!(mean.abs() < 0.06, "mean {mean}");
        assert!((var - std * std).abs() < 0.25, "var {var} vs {}", std * std);
    }

    #[test]
    fn zero_rows_is_a_valid_empty_stack() {
        let mut rng = Rng::seed_from(11);
        let p = StructuredProjection::rademacher_for_segments(5, &[0, 0, 0], &mut rng);
        assert_eq!(p.rows(), 0);
        assert_eq!(p.n_blocks(), 0);
        let z = p.project_batch(&Matrix::zeros(3, 5), 2);
        assert_eq!((z.rows(), z.cols()), (3, 0));
    }
}
