//! Minimal property-based testing support.
//!
//! `proptest`/`quickcheck` are not reachable offline, so this module
//! provides the 10% of them the test suite needs: seeded generators and
//! a `forall` runner with simple halving/shrink-to-smaller reruns for
//! sized inputs. Failures report the seed and the shrunk case.

use crate::rng::Rng;

/// A reproducible generator of test cases.
pub trait Gen {
    type Value;
    /// Generate a case at the given size bound.
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Configuration for [`forall`].
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum size bound passed to the generator (ramped from 1).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `check` on `config.cases` generated inputs; on the first failure,
/// retry at smaller sizes (a crude shrink) and panic with the seed, the
/// failing size and the case's `Debug` form.
pub fn forall<G>(config: PropConfig, gen: G, check: impl Fn(&G::Value) -> Result<(), String>)
where
    G: Gen,
    G::Value: std::fmt::Debug,
{
    let mut rng = Rng::seed_from(config.seed);
    for case_idx in 0..config.cases {
        // Ramp the size bound like proptest does.
        let size = 1 + (config.max_size - 1) * case_idx / config.cases.max(1);
        let mut case_rng = rng.split();
        let value = gen.generate(&mut case_rng, size);
        if let Err(msg) = check(&value) {
            // Shrink: replay smaller sizes from the same stream.
            let mut shrunk: Option<(usize, G::Value, String)> = None;
            let mut s = size / 2;
            while s >= 1 {
                let mut shrink_rng = Rng::seed_from(config.seed ^ (s as u64) << 32 | case_idx as u64);
                let v = gen.generate(&mut shrink_rng, s);
                if let Err(m) = check(&v) {
                    shrunk = Some((s, v, m));
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            match shrunk {
                Some((s, v, m)) => panic!(
                    "property failed (seed={:#x}, case {case_idx}, shrunk to size {s}):\n  {m}\n  case: {v:?}",
                    config.seed
                ),
                None => panic!(
                    "property failed (seed={:#x}, case {case_idx}, size {size}):\n  {msg}\n  case: {value:?}",
                    config.seed
                ),
            }
        }
    }
}

/// Generator helpers.
pub mod gens {
    use crate::rng::Rng;

    /// A vector of `len` f32s in [-1, 1].
    pub fn f32_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    /// A unit-norm vector of dimension `d` (d >= 1).
    pub fn unit_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d.max(1)).map(|_| rng.normal() as f32).collect();
        crate::linalg::normalize(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            PropConfig { cases: 50, ..Default::default() },
            |rng: &mut Rng, size: usize| gens::f32_vec(rng, size),
            |v| {
                if v.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            PropConfig { cases: 50, ..Default::default() },
            |_rng: &mut Rng, size: usize| size,
            |&s| if s < 10 { Ok(()) } else { Err(format!("size {s} too big")) },
        );
    }

    #[test]
    fn deterministic_given_seed() {
        // Two runs with the same seed generate the same cases.
        let collect = |seed: u64| {
            let mut out = Vec::new();
            let out_ref = std::cell::RefCell::new(&mut out);
            forall(
                PropConfig { cases: 10, seed, ..Default::default() },
                |rng: &mut Rng, size: usize| gens::f32_vec(rng, size),
                |v| {
                    out_ref.borrow_mut().push(v.clone());
                    Ok(())
                },
            );
            out
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }

    #[test]
    fn unit_vec_is_unit() {
        let mut rng = Rng::seed_from(1);
        for d in [1, 5, 64] {
            let v = gens::unit_vec(&mut rng, d);
            assert!((crate::linalg::norm2(&v) - 1.0).abs() < 1e-5);
        }
    }
}
