//! Stub of the `xla` crate API surface rfdot uses.
//!
//! Host-side [`Literal`] is fully functional (the tensor marshalling
//! tests exercise it); everything that would need the PJRT runtime
//! ([`PjRtClient::cpu`] and the compile/execute chain behind it) returns
//! [`Error`] so callers degrade to their "PJRT unavailable" paths.

use std::fmt;

/// Stub error: a message, `Display`-compatible with the real crate's
/// error formatting at the call sites rfdot uses.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: rfdot was built against the in-tree xla stub; \
         point the `xla` dependency at an xla_extension build to serve artifacts"
            .into(),
    )
}

/// Element types a [`Literal`] can read back. Only `f32` exists in this
/// stub (matching the manifests' `dtype: f32` contract).
pub trait Element: Copy {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Host-side literal: a flat `f32` buffer plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Unpack a tuple literal. Stub literals are never tuples (they can
    /// only come from [`Literal::vec1`]), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is not a tuple".into()))
    }
}

/// Stub HLO module handle. Parsing requires the runtime, so
/// construction always fails.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Stub computation handle.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub device buffer (never constructed: no executable can exist).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub compiled executable (never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub PJRT client: construction always fails, which is the single
/// gate every rfdot PJRT path funnels through (`Engine::cpu`).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[2.5]);
        let s = l.reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn pjrt_is_unavailable() {
        let e = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
