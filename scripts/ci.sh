#!/usr/bin/env bash
# Tier-1 gate: build, tests, lints, formatting, plus a smoke run of the
# structured-projection bench sweep (exercises the BENCH_structured.json
# regeneration path; --quick diverts its noisy timings to the temp dir
# so the checked-in baseline is only overwritten by full measured
# runs). Run from anywhere.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo clippy --all-targets -- -D warnings
cargo fmt --check
cargo bench --bench micro -- --quick --only structured
