#!/usr/bin/env bash
# Tier-1 gate: build, tests, lints, formatting, docs, plus smoke runs of
# the bench sweeps and the reproduction report:
#
#  * `cargo doc` runs with `-D warnings` so broken intra-doc links (the
#    paper cross-references added in the rustdoc pass) fail the gate;
#  * the structured/sparse/serve/simd/artifact bench smokes exercise
#    the BENCH_*.json regeneration paths (--quick diverts their noisy
#    timings to the temp dir so checked-in baselines are only
#    overwritten by full measured runs; the sparse smoke also asserts
#    CSR/dense parity inside the bench);
#  * `rfdot map-info --selftest` smokes the artifact layer end to end:
#    RFDM0001/0002 records up-convert to the zero-copy RFDM0003 layout
#    with bit-identical transforms, and recycling shrinks the
#    materialized container;
#  * the test suite runs three times: under auto kernel dispatch, with
#    RFDOT_SIMD=scalar forcing the portable oracle kernels, and with
#    RFDOT_TRACE=1 so every span/ring assertion also holds while
#    tracing is live (including the steady-state allocation-free
#    contract in tests/alloc_free_transform.rs);
#  * `rfdot serve --trace --trace-out` runs a native serving smoke and
#    `rfdot trace-check` validates the Chrome trace it wrote (every
#    begin paired with its end, per thread);
#  * `rfdot serve --listen 127.0.0.1:0` runs the TCP front-end on an
#    ephemeral loopback port and `rfdot net-client --malformed` drives
#    it end to end: ping, list-models, dense/sparse bitwise parity, and
#    two crafted malformed frames that must come back as named error
#    frames; the server's stats line and its trace are then checked;
#  * a second loopback run arms `--faults` with a seeded delay plan
#    (plus deadline/shed/retry knobs) and asserts the stats line shows
#    faults=N>0 — the deterministic fault-injection tier, live through
#    the CLI; the test suite also re-runs once with RFDOT_FAULTS set;
#  * `report --quick` regenerates REPORT.md/REPORT.json into a temp dir
#    and re-parses the JSON through the declared schema, failing on
#    schema drift (the self-check inside `rfdot report`).
#
# Run from anywhere.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
# The full suite again with the kernel dispatcher pinned to the scalar
# oracle: every SIMD-vs-scalar parity assertion must hold when the
# "fast" side *is* the oracle, and any test that silently depended on
# a vector path would surface here.
RFDOT_SIMD=scalar cargo test -q
# And once more with tracing live: span recording must not break any
# contract the suite pins while the flag is off — including the
# steady-state zero-allocation transforms (rings pre-allocate).
RFDOT_TRACE=1 cargo test -q
# And once more with a benign seeded fault plan armed process-wide via
# the environment (1ms delays on a twentieth of socket writes): every
# contract must hold while the failpoint layer is live, not just while
# it is compiled in but disarmed. Tests that need their own plans
# (tests/chaos.rs, tests/serve_shard.rs) install/clear per test, which
# overrides the env arming there.
RFDOT_FAULTS='seed=1,net.write=delay-1:0.05' cargo test -q
cargo clippy --all-targets -- -D warnings
cargo fmt --check
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo bench --bench micro -- --quick --only structured
cargo bench --bench micro -- --quick --only sparse
cargo bench --bench micro -- --quick --only serve-throughput
cargo bench --bench micro -- --quick --only net-roundtrip
cargo bench --bench micro -- --quick --only simd-kernels
cargo bench --bench micro -- --quick --only artifact-load
# Artifact-layer smoke: legacy-record up-conversion, bitwise transform
# parity, and the recycling size win, all behind one subcommand.
cargo run --release --quiet -- map-info --selftest
# bench-diff self-comparison: the regression gate parses the checked-in
# baselines and exits 0 (pending/null samples compare clean), so wiring
# real old-vs-new comparisons later is a one-line change. The simd
# baseline also exercises the cross-axis rule: diffs across different
# top-level `simd` axes are reported but never gate.
cargo run --release --quiet -- bench-diff ../BENCH_serve.json ../BENCH_serve.json --max-regress 5
cargo run --release --quiet -- bench-diff ../BENCH_simd.json ../BENCH_simd.json --max-regress 5
cargo run --release --quiet -- bench-diff ../BENCH_net.json ../BENCH_net.json --max-regress 5
report_dir="$(mktemp -d)"
trap 'rm -rf "$report_dir"' EXIT
# Serving smoke with tracing on: the run must write a Chrome trace that
# the offline validator accepts (balanced begin/end per thread).
cargo run --release --quiet -- serve --native --requests 200 --clients 2 --workers 2 \
    --trace --trace-out "$report_dir/trace.json"
test -s "$report_dir/trace.json"
cargo run --release --quiet -- trace-check "$report_dir/trace.json"
# Network serving smoke: a real TCP front-end on an ephemeral loopback
# port (--conns 3 = the net-client's main connection plus its two
# malformed probes, so the server exits deterministically). net-client
# checks ping, list-models, dense/sparse bitwise parity, and that both
# crafted malformed frames come back as named error frames; afterwards
# the server's consolidated stats line and its Chrome trace are checked.
cargo run --release --quiet -- serve --listen 127.0.0.1:0 --conns 3 \
    --trace --trace-out "$report_dir/net_trace.json" > "$report_dir/serve.log" 2>&1 &
serve_pid=$!
addr=""
for _ in $(seq 1 100); do
    addr="$(sed -n 's/^listening on \([^ ]*\).*/\1/p' "$report_dir/serve.log")"
    [ -n "$addr" ] && break
    sleep 0.1
done
test -n "$addr"
cargo run --release --quiet -- net-client --connect "$addr" --requests 8 --malformed
wait "$serve_pid"
grep -q 'model default' "$report_dir/serve.log"
test -s "$report_dir/net_trace.json"
cargo run --release --quiet -- trace-check "$report_dir/net_trace.json"
# Seeded chaos smoke: the same front-end with a deterministic fault
# plan injecting 1ms delays on half of all socket reads/writes, the
# per-request deadline and load-shed knobs armed at harmless levels,
# and the client driving it with its survival knobs (socket deadline +
# retry budget) set. The run must exit clean AND the stats line must
# report faults=N with N > 0 — the plan really fired, the tier really
# survived it. The schedule is a pure function of seed 7, so this
# smoke is bit-reproducible.
cargo run --release --quiet -- serve --listen 127.0.0.1:0 --conns 1 \
    --faults 'seed=7,net.read=delay-1:0.5,net.write=delay-1:0.5' \
    --deadline-ms 2000 --shed 64 > "$report_dir/chaos.log" 2>&1 &
chaos_pid=$!
addr=""
for _ in $(seq 1 100); do
    addr="$(sed -n 's/^listening on \([^ ]*\).*/\1/p' "$report_dir/chaos.log")"
    [ -n "$addr" ] && break
    sleep 0.1
done
test -n "$addr"
cargo run --release --quiet -- net-client --connect "$addr" --requests 8 \
    --timeout-ms 5000 --retries 3
wait "$chaos_pid"
grep -Eq 'faults=[1-9][0-9]*' "$report_dir/chaos.log"
cargo run --release --quiet -- report --quick --fresh --out-dir "$report_dir"
test -s "$report_dir/REPORT.md" && test -s "$report_dir/REPORT.json"
