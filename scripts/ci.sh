#!/usr/bin/env bash
# Tier-1 gate: build, tests, lints, formatting, plus smoke runs of the
# structured-projection and sparse-transform bench sweeps (exercising
# the BENCH_structured.json / BENCH_sparse.json regeneration paths;
# --quick diverts their noisy timings to the temp dir so the checked-in
# baselines are only overwritten by full measured runs — the sparse
# smoke also asserts CSR/dense parity inside the bench). Run from
# anywhere.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo clippy --all-targets -- -D warnings
cargo fmt --check
cargo bench --bench micro -- --quick --only structured
cargo bench --bench micro -- --quick --only sparse
