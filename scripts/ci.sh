#!/usr/bin/env bash
# Tier-1 gate: build, tests, formatting. Run from anywhere.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo fmt --check
