//! End-to-end driver (the EXPERIMENTS.md headline run): the paper's
//! Table 1 protocol on two UCI surrogates with both kernels, through the
//! full pipeline — dataset generation, exact kernel SVM baseline (SMO),
//! Random Maclaurin + linear SVM, H0/1 + linear SVM — reporting the
//! paper's columns: accuracy, train time, test time, speedups.
//!
//! Run: `cargo run --release --example uci_classification [-- --scale 0.1]`
//!
//! `--scale 1.0` reproduces the paper's full dataset sizes (slow);
//! the default 0.1 keeps the run laptop-sized while preserving the
//! qualitative shape (RF ≈ exact accuracy, 1-2 orders of magnitude
//! speedup at test time).

use rfdot::cli::commands::print_rows;
use rfdot::config::{ExperimentConfig, KernelSpec};

fn main() -> rfdot::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut scale = 0.1;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--scale" && i + 1 < args.len() {
            scale = args[i + 1].parse().unwrap_or(scale);
            i += 1;
        }
        i += 1;
    }

    let cases = [
        ("nursery", KernelSpec::Polynomial { degree: 10, offset: 1.0 }, 500, 100),
        ("nursery", KernelSpec::Exponential { sigma2: 0.0 }, 500, 100),
        ("spambase", KernelSpec::Polynomial { degree: 10, offset: 1.0 }, 500, 50),
        ("spambase", KernelSpec::Exponential { sigma2: 0.0 }, 500, 50),
    ];

    let mut rows = Vec::new();
    for (dataset, kernel, d_rf, d_h01) in cases {
        let config = ExperimentConfig {
            dataset: dataset.into(),
            kernel,
            scale,
            n_features: d_rf,
            seed: 42,
            ..Default::default()
        };
        eprintln!("running {dataset} / {:?} ...", config.kernel);
        rows.push(rfdot::bench::run_row(&config, d_rf, d_h01)?);
    }
    println!("\n== Table 1 protocol (scale {scale}) ==");
    print_rows(&rows);
    println!("\npaper shape to check: RF accuracy within a few points of K+SMO;");
    println!("H0/1 competitive at 5-10x fewer random features; large tst speedups.");
    Ok(())
}
