//! Serving example: the L3 coordinator in front of the AOT-compiled
//! JAX/Pallas `transform` artifact, under a concurrent client load.
//! Python is not running — the artifact was compiled by `make artifacts`
//! and is executed through PJRT from Rust worker threads.
//!
//! Falls back to the native engine (same math, pure Rust) when the
//! artifacts are missing, so the example always runs.
//!
//! Run: `make artifacts && cargo run --release --example serve_features`

use rfdot::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, NativeFactory, PjrtTransformFactory,
};
use rfdot::kernels::Exponential;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::metrics::Stopwatch;
use rfdot::rng::Rng;
use rfdot::runtime::ArtifactMeta;
use std::sync::Arc;
use std::time::Duration;

fn main() -> rfdot::Result<()> {
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let artifact = "transform_serve";
    let kernel = Exponential::new(1.0);
    let mut rng = Rng::seed_from(7);

    // Prefer PJRT; fall back to native if `make artifacts` has not run.
    let manifest = artifact_dir.join(format!("{artifact}.json"));
    let (factory, d, engine_name): (Arc<dyn BackendFactory>, usize, &str) = if manifest.exists() {
        let meta = ArtifactMeta::parse(&std::fs::read_to_string(&manifest)?)?;
        let d = meta.inputs[0].shape[1];
        let n_max = meta.inputs[1].shape[0] as u32;
        let features = meta.inputs[1].shape[2];
        let map = Arc::new(RandomMaclaurin::sample(
            &kernel,
            d,
            features,
            RmConfig::default().with_max_order(n_max),
            &mut rng,
        ));
        (
            Arc::new(PjrtTransformFactory::new(&artifact_dir, artifact, map)?),
            d,
            "pjrt (AOT JAX/Pallas artifact)",
        )
    } else {
        eprintln!("artifacts missing; using the native engine (run `make artifacts` for PJRT)");
        let d = 22;
        let map = Arc::new(RandomMaclaurin::sample(
            &kernel,
            d,
            512,
            RmConfig::default().with_max_order(8),
            &mut rng,
        ));
        (Arc::new(NativeFactory::new(map)), d, "native")
    };

    let coord = Arc::new(Coordinator::start(
        factory,
        CoordinatorConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_depth: 8192,
            workers: 2,
            // Native-engine batches may fan out over 2 extra threads.
            intra_op_threads: 2,
            // One work-stealing shard per worker (the default).
            shards: 0,
        },
    ));

    let clients = 4;
    let per_client = 1000;
    println!("engine: {engine_name}");
    println!("load: {clients} clients x {per_client} requests, d = {d}");

    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from(100 + c as u64);
            let mut ok = 0;
            for _ in 0..per_client {
                let mut x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
                rfdot::linalg::normalize(&mut x);
                if let Ok(t) = coord.submit(x) {
                    if t.wait().is_ok() {
                        ok += 1;
                    }
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = sw.elapsed_secs();

    println!("served {total} requests in {:.2}s = {:.0} req/s", dt, total as f64 / dt);
    println!("coordinator: {}", coord.stats().summary());
    for s in coord.shard_snapshots() {
        println!(
            "  shard {}: batches={} items={} steals={} lat p50={:.0}us p90={:.0}us",
            s.shard, s.batches, s.items, s.steals, s.latency_us.p50, s.latency_us.p90
        );
    }
    Ok(())
}
