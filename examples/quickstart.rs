//! Quickstart: approximate a polynomial kernel with Random Maclaurin
//! features and watch the Gram error fall as D grows (paper Figure 1 in
//! miniature), then make a non-linearly-separable problem linearly
//! learnable.
//!
//! Run: `cargo run --release --example quickstart`

use rfdot::data::Dataset;
use rfdot::kernels::{gram, mean_abs_gram_error, DotProductKernel, Polynomial};
use rfdot::linalg::Matrix;
use rfdot::features::{feature_gram, FeatureMap};
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::svm::{Classifier, LinearSvm, LinearSvmParams};

fn main() -> rfdot::Result<()> {
    // ---- 1. kernel approximation --------------------------------------
    let kernel = Polynomial::new(10, 1.0); // K(x,y) = (1 + <x,y>)^10
    let d = 16;
    let mut rng = Rng::seed_from(42);

    // 80 random points on the unit sphere (paper protocol: normalized
    // data, so R = 1 and K ranges up to 2^10).
    let mut rows = Vec::new();
    for _ in 0..80 {
        rows.push(rfdot::prop::gens::unit_vec(&mut rng, d));
    }
    let x = Matrix::from_rows(&rows)?;
    let exact = gram(&kernel, &x);

    println!("Approximating {} (values up to {:.0}):", kernel.name(), kernel.f(1.0));
    println!("{:>8} {:>12} {:>12}", "D", "RF error", "H0/1 error");
    for n_feat in [50, 200, 800, 3200] {
        let rf = RandomMaclaurin::sample(&kernel, d, n_feat, RmConfig::default(), &mut rng);
        let h01 = RandomMaclaurin::sample(
            &kernel,
            d,
            n_feat,
            RmConfig::default().with_h01(true),
            &mut rng,
        );
        let e_rf = mean_abs_gram_error(&exact, &feature_gram(&rf, &x));
        let e_h01 = mean_abs_gram_error(&exact, &feature_gram(&h01, &x));
        println!("{n_feat:>8} {e_rf:>12.4} {e_h01:>12.4}");
    }

    // ---- 2. learning: XOR becomes linear ------------------------------
    // A quadratic concept no linear model can fit...
    let mut xrows = Vec::new();
    let mut y = Vec::new();
    for _ in 0..800 {
        let a = rng.f32() * 2.0 - 1.0;
        let b = rng.f32() * 2.0 - 1.0;
        xrows.push(vec![a, b]);
        y.push(if a * b >= 0.0 { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new("xor", Matrix::from_rows(&xrows)?, y)?;
    let lin_raw = LinearSvm::train(&ds, LinearSvmParams::default())?;

    // ...until Random Maclaurin features linearize it.
    let k2 = rfdot::kernels::Homogeneous::new(2);
    let map = RandomMaclaurin::sample(&k2, 2, 256, RmConfig::default(), &mut rng);
    let z = map.transform_batch(ds.x());
    let zds = Dataset::new("xor-rf", z, ds.y.clone())?;
    let lin_rf = LinearSvm::train(&zds, LinearSvmParams::default())?;

    println!(
        "\nXOR accuracy: raw linear {:.1}%  vs  RM features + linear {:.1}%",
        lin_raw.accuracy_on(&ds) * 100.0,
        lin_rf.accuracy_on(&zds) * 100.0
    );
    Ok(())
}
