//! Kernel k-means and kernel PCA via Random Maclaurin features — the
//! paper's §1 claim that the curse of support afflicts *all*
//! representer-theorem algorithms, and that explicit feature maps fix
//! them uniformly.
//!
//! Workload: XOR-style blobs where each true cluster is a pair of
//! *antipodal* blobs (quadrant (+,+) with (−,−) vs (+,−) with (−,+)).
//! Euclidean k-means cannot group antipodal blobs; the homogeneous
//! quadratic kernel's feature space identifies `x` with `−x`, so
//! k-means over Random Maclaurin features for `⟨x,y⟩²` solves it — with
//! no Gram matrix and no support set.
//!
//! Run: `cargo run --release --example kernel_clustering`

use rfdot::kernels::Homogeneous;
use rfdot::linalg::Matrix;
use rfdot::features::FeatureMap;
use rfdot::maclaurin::{RandomMaclaurin, RmConfig};
use rfdot::rng::Rng;
use rfdot::unsup::{kmeans, pca, KMeansParams};

/// Four blobs in the quadrant corners; label = quadrant parity.
fn antipodal_blobs(n_per: usize, rng: &mut Rng) -> (Matrix, Vec<usize>) {
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for (cx, cy) in [(1.0f32, 1.0f32), (-1.0, -1.0), (1.0, -1.0), (-1.0, 1.0)] {
        let cls = usize::from(cx * cy < 0.0);
        for _ in 0..n_per {
            rows.push(vec![
                cx + 0.25 * rng.normal() as f32,
                cy + 0.25 * rng.normal() as f32,
            ]);
            labels.push(cls);
        }
    }
    (Matrix::from_rows(&rows).unwrap(), labels)
}

fn cluster_accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    let direct = pred.iter().zip(truth).filter(|&(a, b)| a == b).count();
    let flipped = pred.iter().zip(truth).filter(|&(&a, &b)| a != b).count();
    direct.max(flipped) as f64 / pred.len() as f64
}

fn main() -> rfdot::Result<()> {
    let mut rng = Rng::seed_from(17);
    let (x, truth) = antipodal_blobs(200, &mut rng);

    // Raw k-means: antipodal blobs are maximally far apart — hopeless.
    let raw = kmeans(&x, KMeansParams { k: 2, ..Default::default() }, &mut rng)?;
    let raw_acc = cluster_accuracy(&raw.assign_batch(&x), &truth);

    // RM features for <x,y>^2: the feature space identifies x and −x.
    let kernel = Homogeneous::new(2);
    let map = RandomMaclaurin::sample(&kernel, 2, 256, RmConfig::default(), &mut rng);
    let z = map.transform_batch(&x);
    let km = kmeans(&z, KMeansParams { k: 2, ..Default::default() }, &mut rng)?;
    let rf_acc = cluster_accuracy(&km.assign_batch(&z), &truth);

    println!("antipodal-blob clustering (k-means, k=2):");
    println!("  raw input space   : {:.1}% (antipodal pairs cannot merge)", raw_acc * 100.0);
    println!("  RM feature space  : {:.1}%", rf_acc * 100.0);
    assert!(rf_acc > raw_acc + 0.2, "feature-space clustering should win decisively");

    // Kernel PCA via the same features: the top quadratic component is
    // essentially the x·y monomial, which splits the two classes.
    let model = pca(&z, 2, 60)?;
    let proj = model.project_batch(&z);
    let mut vals: Vec<f32> = (0..proj.rows()).map(|i| proj.get(i, 0)).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let thresh = vals[vals.len() / 2];
    let pred: Vec<usize> =
        (0..proj.rows()).map(|i| usize::from(proj.get(i, 0) > thresh)).collect();
    let pca_acc = cluster_accuracy(&pred, &truth);
    println!("kernel PCA (top-component threshold): {:.1}%", pca_acc * 100.0);
    println!(
        "explained variance: [{:.3}, {:.3}]",
        model.variances[0], model.variances[1]
    );
    Ok(())
}
