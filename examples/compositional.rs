//! Compositional kernels (paper §5, Algorithm 2): build feature maps for
//! `K_co(x, y) = f(K_rbf(x, y))` — a dot product kernel composed with an
//! arbitrary PD kernel — using black-box Random Fourier scalar features
//! as the inner map, verify the approximation, and train a classifier
//! on a dataset where the composed kernel helps.
//!
//! Run: `cargo run --release --example compositional`

use rfdot::data::Dataset;
use rfdot::kernels::{DotProductKernel, Exponential, Polynomial};
use rfdot::linalg::{dot, Matrix};
use rfdot::features::FeatureMap;
use rfdot::maclaurin::{CompositionalMaclaurin, RmConfig};
use rfdot::rff::{rbf, RffScalarFactory};
use rfdot::rng::Rng;
use rfdot::svm::{Classifier, LinearSvm, LinearSvmParams};

fn main() -> rfdot::Result<()> {
    let mut rng = Rng::seed_from(11);
    let d = 8;
    let gamma = 1.0;

    // ---- 1. approximation quality --------------------------------------
    // K_co = (1 + K_rbf)^3 and K_co = exp(K_rbf / 2).
    let outers: Vec<(Box<dyn DotProductKernel>, &str)> = vec![
        (Box::new(Polynomial::new(3, 1.0)), "(1 + K_rbf)^3"),
        (Box::new(Exponential::new(2.0)), "exp(K_rbf / 2)"),
    ];
    println!("compositional approximation, inner = RBF(gamma={gamma}), d={d}:");
    println!("{:>16} {:>8} {:>12}", "kernel", "D", "mean |err|");
    for (outer, label) in &outers {
        for n_feat in [256usize, 1024, 4096] {
            let map = CompositionalMaclaurin::sample(
                outer.as_ref(),
                RffScalarFactory::new(gamma, d),
                n_feat,
                RmConfig::default(),
                &mut rng,
            );
            // Error over random pairs.
            let mut err = 0.0;
            let pairs = 50;
            for s in 0..pairs {
                let x = rfdot::prop::gens::unit_vec(&mut Rng::seed_from(300 + s), d);
                let y = rfdot::prop::gens::unit_vec(&mut Rng::seed_from(600 + s), d);
                let exact = outer.f(rbf(gamma, &x, &y));
                let approx = dot(&map.transform(&x), &map.transform(&y)) as f64;
                err += (exact - approx).abs();
            }
            println!("{label:>16} {n_feat:>8} {:>12.4}", err / pairs as f64);
        }
    }

    // ---- 2. learning with composed features ----------------------------
    // Concentric spheres: a radial concept, ideal for an RBF-composed
    // kernel and hopeless for a raw linear model.
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for i in 0..1200 {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let r = rfdot::linalg::norm2(&v);
        let target = if i % 2 == 0 { 0.5f32 } else { 1.0 };
        for vi in v.iter_mut() {
            *vi *= target / r.max(1e-6);
        }
        rows.push(v);
        y.push(if target < 0.75 { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new("rings", Matrix::from_rows(&rows)?, y)?;

    let raw = LinearSvm::train(&ds, LinearSvmParams::default())?;
    let outer = Exponential::new(2.0);
    let map = CompositionalMaclaurin::sample(
        &outer,
        RffScalarFactory::new(gamma, d),
        512,
        RmConfig::default(),
        &mut rng,
    );
    let z = map.transform_batch(ds.x());
    let zds = Dataset::new("rings-co", z, ds.y.clone())?;
    let composed = LinearSvm::train(&zds, LinearSvmParams::default())?;

    println!(
        "\nconcentric spheres accuracy: raw linear {:.1}%  vs  compositional features {:.1}%",
        raw.accuracy_on(&ds) * 100.0,
        composed.accuracy_on(&zds) * 100.0
    );
    Ok(())
}
