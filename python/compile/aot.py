"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For every config in `manifest.CONFIGS` this writes

    artifacts/<name>.hlo.txt   the lowered module
    artifacts/<name>.json      shapes + argument order for the Rust side

Usage: python -m compile.aot [--out DIR] [--only NAME]
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import manifest, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def build_fn(name: str):
    """The jittable function + example args for a manifest entry."""
    cfg = manifest.CONFIGS[name]
    kind = cfg["kind"]
    specs = [_spec(i["shape"]) for i in manifest.artifact_inputs(name)]
    if kind == "transform":

        def fn(x, omega, mask, coeff):
            return (model.rm_transform(x, omega, mask, coeff),)

    elif kind == "transform_score":

        def fn(x, omega, mask, coeff, w, b):
            return (model.transform_score(x, omega, mask, coeff, w, b),)

    elif kind == "train_step":

        def fn(w, b, z, y, lr, reg):
            return model.train_step(w, b, z, y, lr, reg)

    else:
        raise ValueError(f"unknown kind {kind}")
    return fn, specs


def emit(name: str, out_dir: pathlib.Path) -> pathlib.Path:
    """Lower one artifact and write the .hlo.txt + .json pair."""
    fn, specs = build_fn(name)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_dir.mkdir(parents=True, exist_ok=True)
    hlo_path = out_dir / f"{name}.hlo.txt"
    hlo_path.write_text(text)
    meta = {
        "name": name,
        "config": manifest.CONFIGS[name],
        "inputs": manifest.artifact_inputs(name),
        "outputs": manifest.artifact_outputs(name),
        "format": "hlo-text/return-tuple",
    }
    (out_dir / f"{name}.json").write_text(json.dumps(meta, indent=2) + "\n")
    return hlo_path


@functools.cache
def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[2]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out", default=str(_repo_root() / "artifacts"), help="output directory"
    )
    parser.add_argument("--only", default=None, help="emit a single artifact")
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out)
    names = [args.only] if args.only else list(manifest.CONFIGS)
    for name in names:
        path = emit(name, out_dir)
        print(f"wrote {path} ({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
