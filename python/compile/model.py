"""L2: the JAX compute graph built on the L1 Pallas kernel.

Everything here is build-time only: `aot.py` lowers these functions to
HLO text once, and the Rust coordinator executes the compiled artifacts
through PJRT. Python never runs on the request path.

Exported graphs:

* :func:`rm_transform`       — feature map application (the paper's hot
  path: test-time feature construction).
* :func:`transform_score`    — transform fused with a linear scorer, the
  serving path's single-artifact fast route (one PJRT call per batch).
* :func:`train_step`         — one squared-hinge SGD step on transformed
  features, so the coordinator can run linear-model training through
  PJRT too (online-learning mode of the serving example).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.rm_features import rm_features


def rm_transform(x, omega, mask, coeff, *, interpret: bool = True):
    """Z = RM(x): [B, d] -> [B, D] via the Pallas kernel."""
    return rm_features(x, omega, mask, coeff, interpret=interpret)


def linear_score(z, w, b):
    """Decision values of a linear model: [B, D] @ [D] + b -> [B]."""
    return z @ w + b


def transform_score(x, omega, mask, coeff, w, b, *, interpret: bool = True):
    """Fused feature map + linear scorer: [B, d] -> [B] decisions.

    One artifact, one PJRT dispatch per batch; XLA fuses the elementwise
    chain after the kernel's matmuls.
    """
    z = rm_transform(x, omega, mask, coeff, interpret=interpret)
    return linear_score(z, w, b)


def train_step(w, b, z, y, lr, reg):
    """One SGD step on L2-regularized squared hinge loss.

    loss = 0.5 * reg * ||w||^2 + mean(max(0, 1 - y * s)^2),  s = z @ w + b

    Args:
      w: [D] weights; b: scalar bias; z: [B, D] features; y: [B] ±1
      labels; lr/reg: scalars.

    Returns: (w', b', loss) — donated-style functional update.
    """
    s = z @ w + b
    margin = jnp.maximum(0.0, 1.0 - y * s)
    loss = 0.5 * reg * jnp.sum(w * w) + jnp.mean(margin * margin)
    # d loss / d s = -2 y margin / B
    g_s = -2.0 * y * margin / z.shape[0]
    g_w = reg * w + z.T @ g_s
    g_b = jnp.sum(g_s)
    return w - lr * g_w, b - lr * g_b, loss


def train_epoch(w, b, z, y, lr, reg, steps: int):
    """`steps` full-batch updates rolled into one artifact via scan."""

    def body(carry, _):
        w, b = carry
        w2, b2, loss = train_step(w, b, z, y, lr, reg)
        return (w2, b2), loss

    (w, b), losses = jax.lax.scan(body, (w, b), None, length=steps)
    return w, b, losses
