"""Reader/writer for the canonical `.rfdm` Random Maclaurin map blobs.

The Rust library serializes sampled maps (`maclaurin::serialize`) into
this format; the Python build path reads them to expand the exact same
map into the dense `omega / mask / coeff` tensors the AOT artifact
consumes. A writer is provided too so the pytest suite can round-trip
without Rust in the loop.

Layout (little-endian) — must stay in sync with
`rust/src/maclaurin/serialize.rs`:

    magic   8   b"RFDM0001"
    d       u32
    D       u32
    p       f64
    h01     u8
    maxord  u32
    wconst  f32
    wlin    f32
    klen    u32, then klen bytes of utf-8 kernel name
    orders  u32 x D
    weights f32 x D
    rows    u32
    words   u64 x (rows * ceil(d / 64))
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

MAGIC = b"RFDM0001"


@dataclasses.dataclass
class RmMap:
    """A sampled Random Maclaurin map (mirror of the Rust struct)."""

    d: int
    n_random: int
    p: float
    h01: bool
    max_order: int
    w_const: float
    w_linear: float
    kernel_name: str
    orders: np.ndarray  # uint32 [D]
    weights: np.ndarray  # float32 [D]
    words: np.ndarray  # uint64 [rows * words_per_row]

    @property
    def rows(self) -> int:
        return int(self.orders.sum())

    @property
    def words_per_row(self) -> int:
        return (self.d + 63) // 64

    def signs(self) -> np.ndarray:
        """Expand packed words to a dense ±1.0 matrix [rows, d]."""
        w = self.words.reshape(self.rows, self.words_per_row)
        # bit k of word j encodes coordinate j*64+k; set bit => -1.
        bits = np.zeros((self.rows, self.words_per_row * 64), dtype=bool)
        for k in range(64):
            bits[:, k::64] = (w >> np.uint64(k)) & np.uint64(1)
        return np.where(bits[:, : self.d], -1.0, 1.0).astype(np.float32)

    def padded_dense(self, n_max: int):
        """Expand into (omega [n_max, d, D], mask [n_max, D], coeff [D]).

        Mirrors `RandomMaclaurin::to_padded_dense` exactly: padded slots
        hold zeros in omega and mask, so the artifact's
        `mask * (x @ omega_j) + (1 - mask)` contributes a multiplicative
        identity for them.
        """
        if self.orders.max(initial=0) > n_max:
            raise ValueError(
                f"sampled order {self.orders.max()} exceeds padding {n_max}"
            )
        dense = self.signs()
        omega = np.zeros((n_max, self.d, self.n_random), dtype=np.float32)
        mask = np.zeros((n_max, self.n_random), dtype=np.float32)
        offsets = np.concatenate([[0], np.cumsum(self.orders)]).astype(np.int64)
        for i in range(self.n_random):
            n = int(self.orders[i])
            for j in range(n):
                omega[j, :, i] = dense[offsets[i] + j]
                mask[j, i] = 1.0
        return omega, mask, self.weights.astype(np.float32)


def loads(buf: bytes) -> RmMap:
    """Parse an `.rfdm` blob."""
    if buf[:8] != MAGIC:
        raise ValueError("bad RFDM magic")
    off = 8
    d, n_random = struct.unpack_from("<II", buf, off)
    off += 8
    (p,) = struct.unpack_from("<d", buf, off)
    off += 8
    h01 = buf[off] != 0
    off += 1
    (max_order,) = struct.unpack_from("<I", buf, off)
    off += 4
    w_const, w_linear = struct.unpack_from("<ff", buf, off)
    off += 8
    (klen,) = struct.unpack_from("<I", buf, off)
    off += 4
    kernel_name = buf[off : off + klen].decode("utf-8")
    off += klen
    orders = np.frombuffer(buf, dtype="<u4", count=n_random, offset=off).copy()
    off += 4 * n_random
    weights = np.frombuffer(buf, dtype="<f4", count=n_random, offset=off).copy()
    off += 4 * n_random
    (rows,) = struct.unpack_from("<I", buf, off)
    off += 4
    if rows != orders.sum():
        raise ValueError("row count does not match order sum")
    wpr = (d + 63) // 64
    nwords = rows * wpr
    words = np.frombuffer(buf, dtype="<u8", count=nwords, offset=off).copy()
    off += 8 * nwords
    if off != len(buf):
        raise ValueError("trailing bytes in RFDM blob")
    return RmMap(
        d=d,
        n_random=n_random,
        p=p,
        h01=h01,
        max_order=max_order,
        w_const=w_const,
        w_linear=w_linear,
        kernel_name=kernel_name,
        orders=orders,
        weights=weights,
        words=words,
    )


def load(path) -> RmMap:
    with open(path, "rb") as f:
        return loads(f.read())


def dumps(m: RmMap) -> bytes:
    """Serialize (inverse of :func:`loads`)."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<II", m.d, m.n_random)
    out += struct.pack("<d", m.p)
    out += bytes([1 if m.h01 else 0])
    out += struct.pack("<I", m.max_order)
    out += struct.pack("<ff", m.w_const, m.w_linear)
    kname = m.kernel_name.encode("utf-8")
    out += struct.pack("<I", len(kname))
    out += kname
    out += np.asarray(m.orders, dtype="<u4").tobytes()
    out += np.asarray(m.weights, dtype="<f4").tobytes()
    out += struct.pack("<I", int(m.orders.sum()))
    out += np.asarray(m.words, dtype="<u8").tobytes()
    return bytes(out)


def pack_signs(signs: np.ndarray) -> np.ndarray:
    """Pack a ±1 matrix [rows, d] into the bit-word layout (−1 ⇒ bit set)."""
    rows, d = signs.shape
    wpr = (d + 63) // 64
    words = np.zeros((rows, wpr), dtype=np.uint64)
    for j in range(d):
        bit = (signs[:, j] < 0).astype(np.uint64)
        words[:, j // 64] |= bit << np.uint64(j % 64)
    return words.reshape(-1)


def sample_map(
    d: int,
    n_random: int,
    coeffs,
    *,
    p: float = 2.0,
    max_order: int = 8,
    seed: int = 0,
    kernel_name: str = "python-sampled",
) -> RmMap:
    """Sample a map in Python (for tests that do not involve Rust).

    `coeffs[n]` are the Maclaurin coefficients a_n for n <= max_order.
    Uses the same capped-geometric external measure as the Rust sampler
    (tail mass lands on the cap; importance weight uses the emission
    probability) but numpy's RNG, so the *distribution* matches while the
    draws differ.
    """
    rng = np.random.default_rng(seed)
    q = 1.0 / p
    u = rng.random(n_random)
    orders = np.minimum(
        np.floor(np.log(1.0 - u) / np.log(q)).astype(np.int64), max_order
    ).astype(np.uint32)

    def pmf_capped(n):
        return (1 - q) * q**n if n < max_order else q**max_order

    coeffs = np.asarray(coeffs, dtype=np.float64)
    a = np.zeros(max_order + 1)
    a[: min(len(coeffs), max_order + 1)] = coeffs[: max_order + 1]
    weights = np.array(
        [
            np.sqrt(a[n] / pmf_capped(int(n))) / np.sqrt(n_random)
            for n in orders
        ],
        dtype=np.float32,
    )
    rows = int(orders.sum())
    signs = rng.choice([1.0, -1.0], size=(rows, d)).astype(np.float32)
    return RmMap(
        d=d,
        n_random=n_random,
        p=p,
        h01=False,
        max_order=max_order,
        w_const=0.0,
        w_linear=0.0,
        kernel_name=kernel_name,
        orders=orders,
        weights=weights,
        words=pack_signs(signs),
    )
