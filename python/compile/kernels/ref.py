"""Pure-jnp correctness oracles for the Pallas kernel.

Two independent formulations:

* :func:`rm_features_ref` — the padded-dense einsum formulation (same
  math as the kernel, different execution path).
* :func:`rm_features_literal` — the paper's Algorithm 1 verbatim: a
  Python loop over features, each multiplying its own ragged list of
  Rademacher projections. Slow, but bit-for-bit the published
  construction; validating the padded formulation against it is what
  justifies the TPU restructuring.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def rm_features_ref(x, omega, mask, coeff):
    """Padded-dense oracle: same contraction as the Pallas kernel.

    x: [B, d], omega: [n_max, d, D], mask: [n_max, D], coeff: [D]
    returns [B, D].
    """
    # P[b, j, i] = sum_k x[b, k] * omega[j, k, i]
    p = jnp.einsum("bd,jdi->bji", x, omega)
    t = mask[None, :, :] * p + (1.0 - mask[None, :, :])
    return coeff[None, :] * jnp.prod(t, axis=1)


def rm_features_literal(x, orders, signs, weights):
    """Algorithm 1, literally (numpy, per-feature ragged loop).

    x: [B, d]; orders: [D] ints; signs: [sum(orders), d] of ±1 rows;
    weights: [D]. Returns [B, D] float64 (the oracle runs in f64 to make
    tolerance comparisons one-sided).
    """
    x = np.asarray(x, dtype=np.float64)
    signs = np.asarray(signs, dtype=np.float64)
    b = x.shape[0]
    d_out = len(orders)
    out = np.zeros((b, d_out))
    offsets = np.concatenate([[0], np.cumsum(orders)]).astype(int)
    for i in range(d_out):
        prod = np.full(b, float(weights[i]))
        for j in range(offsets[i], offsets[i + 1]):
            prod = prod * (x @ signs[j])
        out[:, i] = prod
    return out
