"""L1: the Random Maclaurin feature map as a Pallas TPU kernel.

The hot spot of the paper's system is applying the sampled map to a
batch: for every output feature `i` with order `N_i` and Rademacher
vectors `w_1..w_{N_i}`, compute `coeff_i * prod_j <w_j, x>`.

Hardware adaptation (DESIGN.md §8): the reference implementations are
CPU loops over ragged per-feature omega lists (BLAS-1). On TPU we
restructure the computation so the MXU does the work — the per-feature
Rademacher stacks are padded along an order axis into dense matrices

    omega: [n_max, d, D]    mask: [n_max, D]    coeff: [D]

and the kernel computes, for each order slot j,

    P_j = X @ omega[j]                        # [B, D] matmul on the MXU
    T_j = mask[j] * P_j + (1 - mask[j])       # padded slots -> identity
    Z   = coeff * prod_j T_j

The `pallas_call` grid tiles over (B, D); each grid step keeps an
`[Bt, d]` X tile and the `[n_max, d, Dt]` omega tile in VMEM and loops
the order axis *inside* the kernel, which is the HBM->VMEM schedule a
CUDA implementation would express with threadblocks. The order loop is
a static Python loop, so it unrolls into n_max fused MXU contractions.

`interpret=True` is required on CPU PJRT — real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute. Correctness is
checked against the pure-jnp oracle in `ref.py` by the pytest suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rm_kernel(x_ref, omega_ref, mask_ref, coeff_ref, out_ref, *, n_max: int):
    """One (B-tile, D-tile) grid step.

    x_ref:     [bB, d]       VMEM tile of the input batch
    omega_ref: [n_max, d, bD] order-padded Rademacher tile
    mask_ref:  [n_max, bD]
    coeff_ref: [1, bD]
    out_ref:   [bB, bD]
    """
    x = x_ref[...]
    acc = None
    for j in range(n_max):  # static unroll: n_max MXU contractions
        p = jnp.dot(x, omega_ref[j], preferred_element_type=jnp.float32)
        m = mask_ref[j][None, :]
        t = m * p + (1.0 - m)
        acc = t if acc is None else acc * t
    if acc is None:  # n_max == 0: every feature is the empty product
        acc = jnp.ones_like(out_ref)
    out_ref[...] = coeff_ref[0][None, :] * acc


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_d", "interpret")
)
def rm_features(
    x: jax.Array,
    omega: jax.Array,
    mask: jax.Array,
    coeff: jax.Array,
    *,
    block_b: int = 128,
    block_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Apply a padded Random Maclaurin map to a batch.

    Args:
      x:     [B, d] float32 input batch.
      omega: [n_max, d, D] order-padded Rademacher stacks (0 in padding).
      mask:  [n_max, D] 1.0 where the order slot is active.
      coeff: [D] per-feature weights (the 1/sqrt(D) scale included).
      block_b / block_d: VMEM tile sizes (clamped to the actual dims).
      interpret: must stay True on CPU PJRT (see module docstring).

    Returns: [B, D] float32 features.
    """
    b, d = x.shape
    n_max, d2, dd = omega.shape
    assert d == d2, f"omega dim {d2} != x dim {d}"
    assert mask.shape == (n_max, dd)
    assert coeff.shape == (dd,)

    if n_max == 0:
        # Degenerate map: every feature is the empty product (= 1).
        return jnp.broadcast_to(coeff[None, :], (b, dd)).astype(jnp.float32)

    bb = min(block_b, b)
    bd = min(block_d, dd)
    # Pallas needs the grid to cover the arrays exactly; fall back to one
    # tile when the dims do not divide.
    if b % bb != 0:
        bb = b
    if dd % bd != 0:
        bd = dd

    grid = (b // bb, dd // bd)
    kernel = functools.partial(_rm_kernel, n_max=n_max)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((n_max, d, bd), lambda i, j: (0, 0, j)),
            pl.BlockSpec((n_max, bd), lambda i, j: (0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, dd), jnp.float32),
        interpret=interpret,
    )(x, omega, mask, coeff.reshape(1, -1))


def vmem_footprint_bytes(
    block_b: int, d: int, n_max: int, block_d: int
) -> int:
    """Estimated VMEM bytes per grid step (f32 words x 4).

    x tile + omega tile + mask/coeff + output accumulator. Used by the
    §Perf analysis in EXPERIMENTS.md; must stay well under ~16 MiB.
    """
    words = (
        block_b * d  # x
        + n_max * d * block_d  # omega
        + n_max * block_d  # mask
        + block_d  # coeff
        + 2 * block_b * block_d  # P_j and the running product
    )
    return 4 * words
