"""Named AOT artifact configurations.

Each entry pins the static shapes one compiled PJRT executable serves.
The Rust runtime reads the emitted `<name>.json` manifests to know the
argument order and shapes; `aot.py` iterates this dict.

Shapes are deliberately few and fixed — the dynamic batcher in the Rust
coordinator pads ragged tails up to `batch` and slices replies, which is
how fixed-shape artifacts serve variable-size request streams.
"""

from __future__ import annotations

# kind: "transform" | "transform_score" | "train_step"
CONFIGS: dict[str, dict] = {
    # Quickstart / cross-engine test artifact (small, fast to compile).
    "transform_quickstart": {
        "kind": "transform",
        "batch": 128,
        "d": 16,
        "n_max": 8,
        "features": 256,
    },
    # Serving artifacts for the IJCNN-surrogate shaped workload (d=22),
    # used by examples/serve_features.rs. Three batch buckets of the
    # same computation: the Rust coordinator routes each dynamic batch
    # to the smallest bucket that fits, cutting padding waste at low
    # occupancy ("one compiled executable per model variant").
    "transform_serve": {
        "kind": "transform",
        "batch": 256,
        "d": 22,
        "n_max": 8,
        "features": 512,
    },
    "transform_serve_b64": {
        "kind": "transform",
        "batch": 64,
        "d": 22,
        "n_max": 8,
        "features": 512,
    },
    "transform_serve_b16": {
        "kind": "transform",
        "batch": 16,
        "d": 22,
        "n_max": 8,
        "features": 512,
    },
    # Fused transform + linear scoring (single dispatch serving route).
    "score_serve": {
        "kind": "transform_score",
        "batch": 256,
        "d": 22,
        "n_max": 8,
        "features": 512,
    },
    # PJRT-side linear training step on transformed features.
    "train_step": {
        "kind": "train_step",
        "batch": 256,
        "features": 512,
    },
}


def artifact_inputs(name: str) -> list[dict]:
    """Describe the input literals (order, shape, dtype) of an artifact."""
    cfg = CONFIGS[name]
    kind = cfg["kind"]
    if kind == "transform":
        return [
            {"name": "x", "shape": [cfg["batch"], cfg["d"]], "dtype": "f32"},
            {
                "name": "omega",
                "shape": [cfg["n_max"], cfg["d"], cfg["features"]],
                "dtype": "f32",
            },
            {"name": "mask", "shape": [cfg["n_max"], cfg["features"]], "dtype": "f32"},
            {"name": "coeff", "shape": [cfg["features"]], "dtype": "f32"},
        ]
    if kind == "transform_score":
        return artifact_inputs_transform_score(cfg)
    if kind == "train_step":
        return [
            {"name": "w", "shape": [cfg["features"]], "dtype": "f32"},
            {"name": "b", "shape": [], "dtype": "f32"},
            {"name": "z", "shape": [cfg["batch"], cfg["features"]], "dtype": "f32"},
            {"name": "y", "shape": [cfg["batch"]], "dtype": "f32"},
            {"name": "lr", "shape": [], "dtype": "f32"},
            {"name": "reg", "shape": [], "dtype": "f32"},
        ]
    raise ValueError(f"unknown kind {kind}")


def artifact_inputs_transform_score(cfg: dict) -> list[dict]:
    return [
        {"name": "x", "shape": [cfg["batch"], cfg["d"]], "dtype": "f32"},
        {
            "name": "omega",
            "shape": [cfg["n_max"], cfg["d"], cfg["features"]],
            "dtype": "f32",
        },
        {"name": "mask", "shape": [cfg["n_max"], cfg["features"]], "dtype": "f32"},
        {"name": "coeff", "shape": [cfg["features"]], "dtype": "f32"},
        {"name": "w", "shape": [cfg["features"]], "dtype": "f32"},
        {"name": "b", "shape": [], "dtype": "f32"},
    ]


def artifact_outputs(name: str) -> list[dict]:
    cfg = CONFIGS[name]
    kind = cfg["kind"]
    if kind == "transform":
        return [
            {"name": "z", "shape": [cfg["batch"], cfg["features"]], "dtype": "f32"}
        ]
    if kind == "transform_score":
        return [{"name": "scores", "shape": [cfg["batch"]], "dtype": "f32"}]
    if kind == "train_step":
        return [
            {"name": "w", "shape": [cfg["features"]], "dtype": "f32"},
            {"name": "b", "shape": [], "dtype": "f32"},
            {"name": "loss", "shape": [], "dtype": "f32"},
        ]
    raise ValueError(f"unknown kind {kind}")
