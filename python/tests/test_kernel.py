"""L1 correctness: the Pallas kernel vs the pure-jnp / literal oracles.

This is the core correctness signal for the compiled hot path: hypothesis
sweeps shapes and order structure, and every case asserts the Pallas
kernel (interpret mode), the padded-dense einsum oracle and the literal
Algorithm 1 loop agree.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.ref import rm_features_literal, rm_features_ref
from compile.kernels.rm_features import rm_features, vmem_footprint_bytes
from compile import rm_map


def make_case(rng, b, d, n_feat, n_max):
    """Random padded map + batch."""
    x = rng.standard_normal((b, d)).astype(np.float32)
    x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)
    orders = rng.integers(0, n_max + 1, size=n_feat)
    signs = rng.choice([1.0, -1.0], size=(int(orders.sum()), d)).astype(np.float32)
    weights = (rng.random(n_feat) * 2.0).astype(np.float32)
    omega = np.zeros((n_max, d, n_feat), dtype=np.float32)
    mask = np.zeros((n_max, n_feat), dtype=np.float32)
    offs = np.concatenate([[0], np.cumsum(orders)]).astype(int)
    for i in range(n_feat):
        for j in range(int(orders[i])):
            omega[j, :, i] = signs[offs[i] + j]
            mask[j, i] = 1.0
    return x, omega, mask, weights, orders, signs


class TestPallasVsOracles:
    @pytest.mark.parametrize(
        "b,d,n_feat,n_max",
        [
            (4, 3, 5, 2),
            (8, 16, 32, 4),
            (128, 16, 256, 8),  # the quickstart artifact shape
            (16, 7, 33, 5),  # ragged tile fallback
            (1, 1, 1, 1),
        ],
    )
    def test_matches_ref_and_literal(self, b, d, n_feat, n_max):
        rng = np.random.default_rng(42 + b + d)
        x, omega, mask, weights, orders, signs = make_case(rng, b, d, n_feat, n_max)
        z_pallas = np.asarray(rm_features(x, omega, mask, weights))
        z_ref = np.asarray(rm_features_ref(x, omega, mask, weights))
        z_lit = rm_features_literal(x, orders, signs, weights)
        np.testing.assert_allclose(z_pallas, z_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(z_pallas, z_lit, rtol=1e-4, atol=1e-5)

    def test_zero_order_features_are_constant(self):
        rng = np.random.default_rng(0)
        b, d, n_feat, n_max = 6, 4, 8, 3
        x, omega, mask, weights, orders, _ = make_case(rng, b, d, n_feat, n_max)
        z = np.asarray(rm_features(x, omega, mask, weights))
        for i in range(n_feat):
            if orders[i] == 0:
                np.testing.assert_allclose(z[:, i], weights[i], rtol=1e-6)

    def test_tile_boundaries(self):
        # Shapes that exactly hit and just miss the default 128 tiles.
        rng = np.random.default_rng(7)
        for b, n_feat in [(128, 128), (256, 384), (129, 130)]:
            x, omega, mask, weights, *_ = make_case(rng, b, 8, n_feat, 4)
            z = np.asarray(rm_features(x, omega, mask, weights))
            z_ref = np.asarray(rm_features_ref(x, omega, mask, weights))
            np.testing.assert_allclose(z, z_ref, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 32),
        d=st.integers(1, 24),
        n_feat=st.integers(1, 48),
        n_max=st.integers(0, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, b, d, n_feat, n_max, seed):
        rng = np.random.default_rng(seed)
        if n_max == 0:
            # All features are empty products.
            x = rng.standard_normal((b, d)).astype(np.float32)
            omega = np.zeros((0, d, n_feat), dtype=np.float32)
            mask = np.zeros((0, n_feat), dtype=np.float32)
            weights = rng.random(n_feat).astype(np.float32)
            z = np.asarray(rm_features(x, omega, mask, weights))
            np.testing.assert_allclose(
                z, np.broadcast_to(weights, (b, n_feat)), rtol=1e-6
            )
            return
        x, omega, mask, weights, orders, signs = make_case(rng, b, d, n_feat, n_max)
        z = np.asarray(rm_features(x, omega, mask, weights))
        z_lit = rm_features_literal(x, orders, signs, weights)
        np.testing.assert_allclose(z, z_lit, rtol=1e-4, atol=1e-5)

    def test_dtype_is_f32(self):
        rng = np.random.default_rng(3)
        x, omega, mask, weights, *_ = make_case(rng, 4, 4, 4, 2)
        z = rm_features(x, omega, mask, weights)
        assert z.dtype == jnp.float32


class TestStatistics:
    def test_unbiased_estimate_of_kernel(self):
        """Lemma 7 in the padded formulation: averaging <Z(x), Z(y)> over
        many sampled maps approaches f(<x, y>) for f = (1 + t)^3."""
        rng = np.random.default_rng(11)
        d, n_feat, n_max = 6, 64, 6
        coeffs = [1.0, 3.0, 3.0, 1.0]  # (1 + t)^3
        x = rng.standard_normal((2, d)).astype(np.float32)
        x /= np.linalg.norm(x, axis=1, keepdims=True)
        t = float(x[0] @ x[1])
        exact = (1.0 + t) ** 3
        acc = 0.0
        n_maps = 150
        for s in range(n_maps):
            m = rm_map.sample_map(d, n_feat, coeffs, max_order=n_max, seed=1000 + s)
            omega, mask, coeff = m.padded_dense(n_max)
            z = np.asarray(rm_features(x, omega, mask, coeff))
            acc += float(z[0] @ z[1])
        mean = acc / n_maps
        assert abs(mean - exact) < 0.35, f"mean {mean} vs exact {exact}"

    def test_estimator_bound(self):
        """Lemma 8: D * |Z_i(x) Z_i(y)| <= p f(p R^2) on the L1 ball."""
        rng = np.random.default_rng(13)
        d, n_feat, n_max = 5, 128, 10
        sigma2 = 1.0
        import math

        coeffs = [1.0 / sigma2**n / math.factorial(n) for n in range(n_max + 1)]
        m = rm_map.sample_map(d, n_feat, coeffs, max_order=n_max, seed=5)
        omega, mask, coeff = m.padded_dense(n_max)
        bound = 2.0 * np.exp(2.0)  # p f(p R^2), p = 2, R = 1, f = exp
        for s in range(20):
            x = rng.standard_normal((2, d)).astype(np.float32)
            x /= np.abs(x).sum(axis=1, keepdims=True)  # L1 ball
            z = np.asarray(rm_features(x, omega, mask, coeff))
            prods = np.abs(z[0] * z[1]) * n_feat
            assert prods.max() <= bound * (1 + 1e-5), f"{prods.max()} > {bound}"


class TestVmem:
    def test_default_tile_fits_vmem(self):
        # DESIGN.md §8: default tile must stay well under 16 MiB.
        bytes_ = vmem_footprint_bytes(128, 128, 8, 128)
        assert bytes_ < 4 * 1024 * 1024, f"VMEM estimate {bytes_} too large"
