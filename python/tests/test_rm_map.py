"""`.rfdm` wire-format tests: roundtrip, bit-packing, padded expansion."""

import numpy as np
import pytest

from compile import rm_map
from compile.kernels.ref import rm_features_literal, rm_features_ref


def test_roundtrip():
    m = rm_map.sample_map(7, 16, [1.0, 2.0, 1.0], seed=3)
    blob = rm_map.dumps(m)
    m2 = rm_map.loads(blob)
    assert m2.d == m.d and m2.n_random == m.n_random
    assert m2.p == m.p and m2.max_order == m.max_order
    np.testing.assert_array_equal(m2.orders, m.orders)
    np.testing.assert_array_equal(m2.weights, m.weights)
    np.testing.assert_array_equal(m2.words, m.words)
    assert m2.kernel_name == m.kernel_name


def test_pack_unpack_signs():
    rng = np.random.default_rng(1)
    for d in [1, 63, 64, 65, 100]:
        signs = rng.choice([1.0, -1.0], size=(5, d)).astype(np.float32)
        words = rm_map.pack_signs(signs)
        m = rm_map.RmMap(
            d=d,
            n_random=5,
            p=2.0,
            h01=False,
            max_order=1,
            w_const=0.0,
            w_linear=0.0,
            kernel_name="t",
            orders=np.ones(5, dtype=np.uint32),
            weights=np.ones(5, dtype=np.float32),
            words=words,
        )
        np.testing.assert_array_equal(m.signs(), signs)


def test_rejects_corruption():
    m = rm_map.sample_map(4, 8, [1.0, 1.0], seed=4)
    blob = rm_map.dumps(m)
    with pytest.raises(ValueError):
        rm_map.loads(b"XXXX" + blob[4:])
    with pytest.raises(Exception):
        rm_map.loads(blob[:-5])
    with pytest.raises(ValueError):
        rm_map.loads(blob + b"\x00")


def test_padded_dense_consistent_with_literal():
    m = rm_map.sample_map(6, 24, [0.5, 1.0, 0.25, 0.125], max_order=5, seed=9)
    omega, mask, coeff = m.padded_dense(5)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((7, 6)).astype(np.float32) * 0.3
    z_ref = np.asarray(rm_features_ref(x, omega, mask, coeff))
    z_lit = rm_features_literal(x, m.orders, m.signs(), m.weights)
    np.testing.assert_allclose(z_ref, z_lit, rtol=1e-4, atol=1e-6)


def test_padded_dense_rejects_small_n_max():
    m = rm_map.sample_map(4, 16, [1.0, 1.0, 1.0], max_order=6, seed=11)
    if m.orders.max() > 2:
        with pytest.raises(ValueError):
            m.padded_dense(2)


def test_order_distribution_is_capped_geometric():
    m = rm_map.sample_map(3, 20000, [1.0] * 9, max_order=8, seed=13)
    frac0 = float((m.orders == 0).mean())
    frac_cap = float((m.orders == 8).mean())
    assert abs(frac0 - 0.5) < 0.02  # pmf(0) = 1/2 at p=2
    assert abs(frac_cap - 2.0**-8) < 0.01  # survival mass at the cap
