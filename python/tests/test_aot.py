"""AOT pipeline tests: lowering to HLO text, manifests, shape agreement.

The quickstart artifact is lowered for real (slow-ish but the critical
path); the rest are validated through the manifest consistency checks.
"""

import json
import pathlib
import tempfile

import numpy as np
import pytest

from compile import aot, manifest


def test_manifest_shapes_consistent():
    for name, cfg in manifest.CONFIGS.items():
        ins = manifest.artifact_inputs(name)
        outs = manifest.artifact_outputs(name)
        assert ins and outs
        if cfg["kind"] in ("transform", "transform_score"):
            assert ins[0]["shape"] == [cfg["batch"], cfg["d"]]
            assert ins[1]["shape"] == [cfg["n_max"], cfg["d"], cfg["features"]]
        if cfg["kind"] == "transform":
            assert outs[0]["shape"] == [cfg["batch"], cfg["features"]]


def test_emit_quickstart_artifact():
    with tempfile.TemporaryDirectory() as td:
        out = pathlib.Path(td)
        path = aot.emit("transform_quickstart", out)
        text = path.read_text()
        assert text.startswith("HloModule"), text[:80]
        # The kernel's matmuls must appear as dot ops.
        assert " dot(" in text or " dot." in text
        meta = json.loads((out / "transform_quickstart.json").read_text())
        assert meta["format"] == "hlo-text/return-tuple"
        assert meta["config"]["features"] == 256


def test_hlo_text_parses_back():
    """The emitted HLO text must re-parse through the same text parser the
    Rust runtime uses (`HloModuleProto::from_text_file` wraps it), with the
    expected entry signature. Full load-and-execute is covered by the Rust
    integration tests (rust/tests/pjrt_roundtrip.rs)."""
    from jax._src.lib import xla_client as xc

    name = "transform_quickstart"
    cfg = manifest.CONFIGS[name]
    fn, specs = aot.build_fn(name)
    import jax

    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)

    module = xc._xla.hlo_module_from_text(text)
    proto = module.as_serialized_hlo_module_proto()
    assert len(proto) > 100
    # Entry signature: 4 parameters, tuple result with the right shape.
    text2 = module.to_string()
    assert f"f32[{cfg['batch']},{cfg['d']}]" in text2
    assert f"f32[{cfg['batch']},{cfg['features']}]" in text2


@pytest.mark.parametrize("name", list(manifest.CONFIGS))
def test_build_fn_traces(name):
    """Every artifact must at least trace (shape-check) cleanly."""
    import jax

    fn, specs = aot.build_fn(name)
    jax.eval_shape(fn, *specs)
