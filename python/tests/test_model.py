"""L2 graph tests: shapes, fusion semantics, training step descent."""

import numpy as np

import jax.numpy as jnp

from compile import model, rm_map


def setup_map(d=8, n_feat=64, n_max=4, seed=0):
    coeffs = [1.0, 2.0, 1.5, 0.5, 0.25]
    m = rm_map.sample_map(d, n_feat, coeffs, max_order=n_max, seed=seed)
    return m.padded_dense(n_max)


def test_transform_shapes():
    omega, mask, coeff = setup_map()
    x = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    z = model.rm_transform(x, omega, mask, coeff)
    assert z.shape == (16, 64)


def test_transform_score_equals_manual():
    omega, mask, coeff = setup_map()
    rng = np.random.default_rng(1)
    x = rng.standard_normal((16, 8)).astype(np.float32)
    w = rng.standard_normal(64).astype(np.float32)
    b = np.float32(0.3)
    fused = model.transform_score(x, omega, mask, coeff, w, b)
    manual = model.rm_transform(x, omega, mask, coeff) @ w + b
    np.testing.assert_allclose(np.asarray(fused), np.asarray(manual), rtol=1e-5)


def test_train_step_reduces_loss():
    rng = np.random.default_rng(2)
    b_sz, d_feat = 64, 32
    z = rng.standard_normal((b_sz, d_feat)).astype(np.float32)
    true_w = rng.standard_normal(d_feat).astype(np.float32)
    y = np.sign(z @ true_w + 0.1).astype(np.float32)
    w = jnp.zeros(d_feat)
    bias = jnp.float32(0.0)
    losses = []
    for _ in range(60):
        w, bias, loss = model.train_step(w, bias, z, y, 0.5, 1e-4)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"no descent: {losses[0]} -> {losses[-1]}"
    acc = float((np.sign(np.asarray(z @ w + bias)) == y).mean())
    assert acc > 0.9, f"train acc {acc}"


def test_train_epoch_matches_unrolled_steps():
    rng = np.random.default_rng(3)
    z = rng.standard_normal((32, 16)).astype(np.float32)
    y = np.sign(rng.standard_normal(32)).astype(np.float32)
    w0 = jnp.zeros(16)
    b0 = jnp.float32(0.0)
    w_scan, b_scan, losses = model.train_epoch(w0, b0, z, y, 0.1, 1e-3, 5)
    w, b = w0, b0
    for _ in range(5):
        w, b, _ = model.train_step(w, b, z, y, 0.1, 1e-3)
    np.testing.assert_allclose(np.asarray(w_scan), np.asarray(w), rtol=1e-5)
    np.testing.assert_allclose(float(b_scan), float(b), rtol=1e-5)
    assert losses.shape == (5,)
